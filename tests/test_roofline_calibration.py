"""Calibration of the roofline pipeline (referenced by EXPERIMENTS.md §Roofline):

  * cost_analysis under SPMD reports PER-CHIP flops/bytes;
  * while-loop bodies are counted once (the reason for the analysis lowering);
  * the HLO collective parser's ring formulas on a known program.

These run a 64-device forced host platform in a subprocess (the main test
process keeps the single default CPU device)."""

from __future__ import annotations

import json
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.launch.hlo_analysis import analyze_collectives

    mesh = jax.make_mesh((8, 8), ("data", "model"))
    ns = lambda s: jax.sharding.NamedSharding(mesh, s)
    n = 1024
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((n, n), jnp.float32)

    out = {}
    # 1. per-chip flops
    c = jax.jit(lambda a, b: a @ b,
                in_shardings=(ns(P("data", None)), ns(P(None, "model")))
                ).lower(x, w).compile()
    out["matmul_flops"] = compat.cost_analysis(c)["flops"]
    out["matmul_expected_per_chip"] = 2 * n**3 / 64

    # 2. while-body counted once
    def scanned(a, b):
        return jax.lax.scan(lambda c_, _: (c_ @ b, None), a, None, length=10)[0]
    c2 = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    out["scan_flops"] = compat.cost_analysis(c2)["flops"]
    out["one_body"] = 2 * 256**3

    # 3. collective parse: resharding a model-sharded tensor to replicated
    #    emits an all-gather over the model axis
    def f(a):
        return jax.lax.with_sharding_constraint(a, ns(P("data", None)))
    g = jax.jit(f, in_shardings=ns(P("data", "model")), out_shardings=ns(P("data", None)))
    c3 = g.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    st = analyze_collectives(c3.as_text(), {"data": 8, "model": 8})
    out["ag_wire"] = st.wire_bytes_per_chip
    out["ag_kinds"] = st.by_kind
    out["ag_axes"] = st.by_axis
    # all-gather over model: out per chip (64/8, 64) f32 = 2048 B? — the
    # resharding gathers the model-sharded dim: out (8, 64) f32 = 2 KiB,
    # wire = out·(n-1)/n
    print(json.dumps(out))
""")


def test_calibration():
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # per-chip flops exact
    assert abs(out["matmul_flops"] - out["matmul_expected_per_chip"]) < 1e6
    # scan counted once (±epsilon), NOT 10×
    assert out["scan_flops"] < 1.2 * out["one_body"]
    # the reshard emitted an all-gather over the model axis with ring bytes
    assert out["ag_wire"] > 0
    assert "model" in out["ag_axes"]
