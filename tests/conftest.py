"""Test bootstrap: put src/ on sys.path (tests run with or without
PYTHONPATH=src), make the tests dir importable (the hypothesis fallback
shim lives here), and keep jax on the default single CPU device — the
512-device XLA flag is set ONLY by launch/dryrun.py."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))
