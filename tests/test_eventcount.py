"""EventCount / Sequencer (paper §1: the TWA transformation applied to the
Reed–Kanodia constructs)."""

from __future__ import annotations

import threading
import time

from repro.core.eventcount import EventCount, Sequencer, TicketMutex


def test_sequencer_dense_unique():
    seq = Sequencer()
    out = []
    lock = threading.Lock()

    def worker():
        for _ in range(200):
            t = seq.ticket()
            with lock:
                out.append(t)

    ts = [threading.Thread(target=worker) for _ in range(6)]
    [t.start() for t in ts]
    [t.join(timeout=30) for t in ts]
    assert sorted(out) == list(range(1200))  # dense, no duplicates


def test_eventcount_await_advance():
    ec = EventCount()
    seen = []

    def waiter(v):
        c = ec.await_(v)
        seen.append((v, c))

    ts = [threading.Thread(target=waiter, args=(v,)) for v in (3, 1, 5)]
    [t.start() for t in ts]
    time.sleep(0.05)
    ec.advance(1)  # enables await(1) only
    time.sleep(0.1)
    assert sorted(v for v, _ in seen) == [1]
    ec.advance(4)  # count=5 — enables 3 and 5
    for t in ts:
        t.join(timeout=30)
        assert not t.is_alive()
    assert sorted(v for v, _ in seen) == [1, 3, 5]
    for v, c in seen:
        assert c >= v  # awaited condition actually held


def test_eventcount_selective_wakeup_buckets():
    """advance(n) pokes only the buckets of the enabled values — waiters far
    beyond the advance are not woken (their buckets untouched, absent
    collisions in a large private array)."""
    from repro.core.twa_semaphore import WaitingArray

    arr = WaitingArray(table_size=2048)
    ec = EventCount(array=arr)
    far = threading.Thread(target=ec.await_, args=(1000,))
    far.start()
    time.sleep(0.05)
    ec.advance(3)
    time.sleep(0.1)
    assert far.is_alive()  # far waiter undisturbed and unenabled
    ec.advance(997)
    far.join(timeout=30)
    assert not far.is_alive()


def test_ticket_mutex_mutual_exclusion():
    m = TicketMutex()
    shared = {"x": 0, "in": 0, "max": 0}
    guard = threading.Lock()

    def worker():
        for _ in range(150):
            m.lock()
            with guard:
                shared["in"] += 1
                shared["max"] = max(shared["max"], shared["in"])
            shared["x"] += 1
            with guard:
                shared["in"] -= 1
            m.unlock()

    ts = [threading.Thread(target=worker) for _ in range(6)]
    [t.start() for t in ts]
    [t.join(timeout=60) for t in ts]
    assert shared["x"] == 900
    assert shared["max"] == 1
