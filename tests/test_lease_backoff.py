"""DistributedTicketLease wait discipline (PR 7 satellite).

  * jittered exponential backoff replaces the fixed poll period: waiters
    under contention still acquire strictly FCFS, and the per-lease retry
    counters (`wait_telemetry`) surface how they waited;
  * lease heartbeats: a waiter renews ``<name>/hb/<ticket>`` while
    queued AND on acquisition; holders renew via :meth:`renew`;
    ``heartbeat_age`` is None for a ticket that never breathed;
  * the tombstone timeout path counts into ``timeouts`` and never wedges
    the grant sequence (the existing cancellation semantics, re-pinned
    under the new backoff loop);
  * seeded jitter is deterministic: two leases with the same
    ``backoff_seed`` draw identical jitter streams.
"""

from __future__ import annotations

import threading
import time

from repro.runtime.coordinator import DistributedTicketLease, KVStore


def test_contended_acquires_fcfs_with_backoff():
    kv = KVStore()
    lease = DistributedTicketLease(kv, "bk", capacity=2, backoff_seed=7,
                                   backoff_base=0.001, backoff_cap=0.02)
    order = []
    lock = threading.Lock()

    def worker(i):
        t = lease.acquire(timeout=10.0)
        with lock:
            order.append((t, i))
        time.sleep(0.01)
        lease.release()

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
        time.sleep(0.002)  # stagger submissions so tickets are ordered
    for t in threads:
        t.join()
    tickets = [t for t, _ in sorted(order)]
    assert len(tickets) == 6 and len(set(tickets)) == 6
    tel = lease.wait_telemetry()
    assert tel["acquires"] == 6
    assert tel["timeouts"] == 0
    assert tel["queue_depth"] == 0
    assert tel["heartbeats"] >= 6  # at least the holder baseline each


def test_heartbeat_renewal_and_age():
    kv = KVStore()
    lease = DistributedTicketLease(kv, "hb", capacity=1, backoff_seed=1)
    assert lease.heartbeat_age(999) is None  # never breathed
    t = lease.acquire(timeout=5.0)
    age = lease.heartbeat_age(t)
    assert age is not None and age < 1.0
    before = lease.retry_counts["heartbeats"]
    lease.renew(t)
    assert lease.retry_counts["heartbeats"] == before + 1
    lease.release()


def test_waiter_renews_heartbeat_while_queued():
    kv = KVStore()
    lease = DistributedTicketLease(kv, "wq", capacity=1, backoff_seed=3,
                                   heartbeat_interval=0.02,
                                   backoff_base=0.001, backoff_cap=0.01)
    lease.acquire(timeout=5.0)  # hold the only slot
    got = []

    def waiter():
        got.append(lease.acquire(timeout=5.0))

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.15)  # the queued waiter must have renewed by now
    waiting_ticket = kv.get("wq/ticket") - 1  # the newest ticket drawn
    assert lease.heartbeat_age(waiting_ticket) is not None
    assert lease.heartbeat_age(waiting_ticket) < 1.0
    lease.release()
    th.join()
    assert got and got[0] == waiting_ticket
    lease.release()


def test_timeout_counts_and_grant_not_wedged():
    kv = KVStore()
    lease = DistributedTicketLease(kv, "to", capacity=1, backoff_seed=5,
                                   backoff_base=0.001, backoff_cap=0.01)
    lease.acquire(timeout=5.0)
    try:
        lease.acquire(timeout=0.1)
        raise AssertionError("second acquire must time out")
    except TimeoutError:
        pass
    assert lease.wait_telemetry()["timeouts"] == 1
    assert lease.retry_counts["near"] + lease.retry_counts["far"] >= 1
    # the tombstoned ticket must not wedge the sequence: release flows
    # the slot past the dead ticket to the next live waiter
    done = []
    th = threading.Thread(
        target=lambda: done.append(lease.acquire(timeout=5.0)))
    th.start()
    lease.release()
    th.join(timeout=5.0)
    assert done, "tombstone wedged the grant sequence"
    lease.release()


def test_backoff_jitter_seed_deterministic():
    a = DistributedTicketLease(KVStore(), "j", backoff_seed=42)
    b = DistributedTicketLease(KVStore(), "j", backoff_seed=42)
    assert [a._jitter.random() for _ in range(8)] == \
        [b._jitter.random() for _ in range(8)]
