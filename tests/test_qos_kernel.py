"""Fused QoS admission kernel (kernels/qos_admission) vs the functional
oracle, plus the PR-2 reference-path invariants:

  * kernel == `qos_round` bit-exactly (interpret mode) across random tenant
    mixes, ticket wrap-around near 2³², all-dead batches, and
    zero-weight/zero-free edge cases — every state field, both row masks,
    and the leftover unit count;
  * blocked-prefix `live_fifo_rank` == the retained O(N²) pairwise oracle;
  * the replenish poke window decays with reclaim (dead-below-frontier)
    instead of growing monotonically with total expirations.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

try:  # hypothesis is an optional test dependency (pyproject `test` extra)
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.admission.functional_qos import (
    QoSState,
    make_qos,
    qos_reclaim,
    qos_replenish,
    qos_round,
    qos_take,
)
from repro.core.functional import live_fifo_rank, live_fifo_rank_pairwise
from repro.kernels.qos_admission import qos_round_fused


def _assert_round_equal(state, ids, tickets, alive, dls, now, free, mu,
                        block_n, tag=""):
    ref = qos_round(state, ids, tickets, alive, dls, now, free, mu)
    ker = qos_round_fused(state, ids, tickets, alive, dls, now, free,
                          max_units=mu, block_n=block_n, interpret=True)
    rs, ra, re, rl = ref
    ks, ka, ke, kl = ker
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(ka), err_msg=tag)
    np.testing.assert_array_equal(np.asarray(re), np.asarray(ke), err_msg=tag)
    for f in QoSState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rs, f)), np.asarray(getattr(ks, f)),
            err_msg=f"{tag}:{f}")
    assert int(rl) == int(kl), (tag, int(rl), int(kl))


def _random_round(seed: int, alive_density: float, expire_density: float,
                  free: int, wrap: bool):
    """Fixed shapes (one compiled kernel), random data: weights (incl. 0),
    tenant mix, per-tenant consecutive tickets (optionally wrapping 2³²),
    alive mask, deadlines."""
    S, N, TBL, MU = 4, 32, 128, 16
    rng = np.random.default_rng(seed)
    state = make_qos(rng.integers(0, 5, S).astype(np.float32), table_size=TBL)
    base = np.uint32((1 << 32) - 13) if wrap else np.uint32(0)
    state = state._replace(
        ticket=jnp.full((S,), base, jnp.uint32),
        grant=jnp.full((S,), base, jnp.uint32),
        consumed=jnp.full((S,), base, jnp.uint32),
        dead=jnp.asarray(rng.integers(0, 3, S), jnp.uint32),
        vpass=jnp.asarray(rng.uniform(0, 2, S), jnp.float32))
    ids = jnp.asarray(rng.integers(0, S, N), jnp.int32)
    state, tickets, _, _ = qos_take(state, ids, jnp.ones(N, bool))
    alive = jnp.asarray(rng.random(N) < alive_density)
    dls = jnp.asarray(np.where(rng.random(N) < expire_density,
                               rng.uniform(-1, 1, N), np.inf), jnp.float32)
    return state, ids, tickets, alive, dls, free, MU


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**32 - 1),   # seed
       st.sampled_from([0.0, 0.3, 0.8, 1.0]),   # alive density (0 = all dead)
       st.sampled_from([0.0, 0.4, 1.0]),        # expire density
       st.integers(0, 20),          # free units
       st.booleans())               # tickets wrap 2³²
def test_qos_kernel_matches_oracle_property(seed, dens, exp, free, wrap):
    state, ids, tickets, alive, dls, free, mu = _random_round(
        seed, dens, exp, free, wrap)
    _assert_round_equal(state, ids, tickets, alive, dls, 0.0, free, mu,
                        block_n=16, tag=f"seed={seed}")


def test_qos_kernel_all_dead_batch():
    state, ids, tickets, _, dls, _, mu = _random_round(3, 1.0, 0.0, 7, False)
    _assert_round_equal(state, ids, tickets, jnp.zeros(32, bool), dls,
                        0.0, 7, mu, block_n=16, tag="all-dead")


def test_qos_kernel_zero_weight_free_units():
    """Zero-weight tenants: at most one unit (their first crossing), then
    their virtual pass saturates to +inf — kernel and oracle agree."""
    state = make_qos([0.0, 0.0, 2.0], table_size=64)
    ids = jnp.asarray([0] * 4 + [1] * 4 + [2] * 4, jnp.int32)
    state, tickets, _, _ = qos_take(state, ids, jnp.ones(12, bool))
    dls = jnp.full((12,), np.inf, jnp.float32)
    _assert_round_equal(state, ids, tickets, jnp.ones(12, bool), dls,
                        0.0, 10, 8, block_n=8, tag="zero-weight")
    # and the round after (vpass now inf for any granted zero-weight tenant)
    s2, admitted, _, _ = qos_round(state, ids, tickets, jnp.ones(12, bool),
                                   dls, 0.0, 10, 8)
    _assert_round_equal(s2, ids, tickets, jnp.ones(12, bool) & ~admitted,
                        dls, 0.0, 4, 8, block_n=8, tag="zero-weight-2")


def test_qos_kernel_ticket_wraparound_multiblock():
    """Per-tenant ticket sequences spanning the 2³² wrap, shuffled row
    order, N spanning several kernel blocks."""
    S, N = 3, 200
    rng = np.random.default_rng(11)
    state = make_qos([4.0, 2.0, 1.0], table_size=256)
    base = np.uint32((1 << 32) - 60)
    state = state._replace(ticket=jnp.full((S,), base, jnp.uint32),
                           grant=jnp.full((S,), base, jnp.uint32),
                           consumed=jnp.full((S,), base, jnp.uint32))
    ids = jnp.asarray(rng.integers(0, S, N), jnp.int32)
    state, tickets, _, _ = qos_take(state, ids, jnp.ones(N, bool))
    perm = rng.permutation(N)
    alive = jnp.asarray(rng.random(N) > 0.3)
    dls = jnp.asarray(np.where(rng.random(N) > 0.5,
                               rng.uniform(0, 2, N), np.inf), jnp.float32)
    _assert_round_equal(state, ids[perm], tickets[perm], alive[perm],
                        dls[perm], 1.0, 9, 12, block_n=64, tag="wrap")


def test_qos_round_empty_backlog():
    """N=0 backlog: reference, blocked rank, and padded kernel wrapper all
    return empty masks and conserve the free units (regression: the
    ticket-order argsort used to gather from an empty array)."""
    from repro.kernels.ops import qos_round as qos_round_ops

    s = make_qos([1.0, 2.0], table_size=64)
    empty_i = jnp.zeros((0,), jnp.int32)
    _, admitted, expired, leftover = qos_round(
        s, empty_i, jnp.zeros((0,), jnp.uint32), jnp.zeros((0,), bool),
        jnp.zeros((0,), jnp.float32), 0.0, 3, 4)
    assert admitted.shape == (0,) and expired.shape == (0,)
    assert int(leftover) == 3
    assert live_fifo_rank(empty_i, jnp.zeros((0,), jnp.uint32),
                          jnp.zeros((0,), bool), 2).shape == (0,)
    _, ka, ke, kl = qos_round_ops(
        s, np.zeros(0, np.int32), np.zeros(0, np.uint32), np.zeros(0, bool),
        np.zeros(0, np.float32), 0.0, 3, max_units=4)
    assert ka.shape == (0,) and ke.shape == (0,) and int(kl) == 3


# ------------------------------------------------- blocked-prefix rank ------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.booleans())
def test_live_fifo_rank_blocked_equals_pairwise(seed, wrap):
    """The O(N·S/block) blocked-prefix rank == the retained O(N²) pairwise
    oracle, for shuffled per-tenant-unique tickets with and without 2³²
    wrap-around, under arbitrary alive masks."""
    rng = np.random.default_rng(seed)
    S, N = 5, 97
    ids = rng.integers(0, S, N).astype(np.int32)
    base = np.uint32((1 << 32) - 40) if wrap else np.uint32(rng.integers(0, 1000))
    tickets = np.zeros(N, np.uint32)
    counters = np.full(S, base, np.uint32)
    for r in range(N):  # per-tenant consecutive (the take-time invariant)
        tickets[r] = counters[ids[r]]
        counters[ids[r]] += np.uint32(1)
    perm = rng.permutation(N)
    ids, tickets = ids[perm], tickets[perm]
    alive = rng.random(N) > 0.25
    got = live_fifo_rank(jnp.asarray(ids), jnp.asarray(tickets),
                         jnp.asarray(alive), S, block=32)
    want = live_fifo_rank_pairwise(jnp.asarray(ids), jnp.asarray(tickets),
                                   jnp.asarray(alive))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -------------------------------------------- poke-window decay (dead) ------


def test_poke_window_decays_with_reclaim():
    """Regression (ROADMAP open item): the conservative replenish poke
    window must NOT grow monotonically with total expirations.  Credit
    granted to demand that then dies is reclaimed, and each reclaimed unit
    absorbs one tombstone's worth of window slack — so repeated
    grant→expire→reclaim cycles keep `dead` bounded by the per-cycle death
    count instead of accumulating 2 per cycle."""
    s = make_qos([1.0], table_size=64)
    deads = []
    for cycle in range(6):
        ids = jnp.zeros((2,), jnp.int32)
        s, tk, _, _ = qos_take(s, ids, jnp.ones(2, bool))
        # grant 2 units to the live demand…
        s, alloc, _ = qos_replenish(s, 2, jnp.asarray([2], jnp.int32),
                                    max_units=4)
        assert int(alloc[0]) == 2
        # …then both waiters die before admission: stranded credit
        s = s._replace(dead=s.dead + jnp.uint32(2))
        s, reclaimed = qos_reclaim(s, jnp.asarray([0], jnp.int32))
        assert int(reclaimed) == 2
        deads.append(int(s.dead[0]))
    assert max(deads) == 0  # fully absorbed every cycle (old: 2·(cycle+1))


def test_poke_window_partial_reclaim_keeps_slack():
    """Unreclaimed tombstones keep their (sound) window slack: only the
    absorbed portion decays."""
    s = make_qos([1.0], table_size=64)
    ids = jnp.zeros((3,), jnp.int32)
    s, tk, _, _ = qos_take(s, ids, jnp.ones(3, bool))
    s, alloc, _ = qos_replenish(s, 1, jnp.asarray([3], jnp.int32), max_units=4)
    s = s._replace(dead=s.dead + jnp.uint32(2))  # two die, one unit stranded?
    # live depth 1 (one waiter left), avail 1 → nothing stranded yet
    s, reclaimed = qos_reclaim(s, jnp.asarray([1], jnp.int32))
    assert int(reclaimed) == 0 and int(s.dead[0]) == 2
    # the last waiter dies too → the unit strands → one tombstone absorbed
    s, reclaimed = qos_reclaim(s, jnp.asarray([0], jnp.int32))
    assert int(reclaimed) == 1 and int(s.dead[0]) == 1


# ------------------------------------------------------ engine (kernel) -----


def test_engine_qos_kernel_path():
    """ContinuousBatchingEngine(use_kernel=True, tenants=…): the fused
    kernel round drives admission — all requests finish, deadline expiry is
    tombstoned, FCFS per tenant holds (admit order == ticket order)."""
    import time

    from repro.serving.scheduler import ContinuousBatchingEngine, Request

    weights = {"a": 2.0, "b": 1.0}
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots=3,
        tenants=weights, use_kernel=True)
    reqs, rid = [], 0
    for _ in range(10):
        for t in weights:
            reqs.append(Request(rid=rid, prompt=[1], max_new_tokens=1,
                                tenant_id=t))
            rid += 1
    doa = Request(rid=rid, prompt=[1], max_new_tokens=1, tenant_id="a",
                  deadline=time.monotonic() - 1.0)
    eng.submit_batch(reqs + [doa])
    steps = 0
    while eng.stats.finished + eng.stats.expired < len(reqs) + 1 and steps < 200:
        eng.step(lambda lg: np.zeros(len(lg), np.int64))
        steps += 1
    assert eng.stats.finished == len(reqs)
    assert doa.expired and doa.done_event.is_set()
    assert eng.stats.expired == 1
    for t in weights:
        admitted = [r for r in reqs if r.tenant_id == t and r.admit_t > 0]
        tks = [r.ticket for r in sorted(admitted, key=lambda r: r.admit_t)]
        assert tks == sorted(tks), t
