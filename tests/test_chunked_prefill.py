"""Continuous chunked-prefill subsystem — the PR-5 tentpole tests:

  * property: with ``chunked_prefill=(chunk, budget)`` configured,
    ``megastep(K)`` stays round-for-round bit-identical to K sequential
    ``step()`` calls (both host QoS modes) under mixed prompt/max_new
    lengths that force incremental takes, parks, and resumes — incl. 2³²
    QoS ticket wrap, deadline preemption of mid-prefill and parked slots,
    and the host↔device block-semaphore mirror (ticket/grant/bucket_seq);
  * property: chunked prefill is **chunk-size invariant** — token streams
    through the real pool-attention model are bit-identical for any chunk
    size AND to the one-shot (worst-case up-front) paged engine;
  * property: incremental allocation preserves the PR-4 block-conservation
    invariant (free ∪ tables = {0..NB−1}, no aliasing) under random
    park/resume interleavings and the block counters crossing 2³²;
  * no-deadlock: a pool far smaller than aggregate demand drains
    completely (every sequence finishes), with parks actually exercised,
    and parked slots resume FCFS in Banker priority order;
  * satellite: submit-time ValueError for requests whose lifetime demand
    exceeds pool capacity (instead of stalling forever), and for prompts
    over ``prompt_cap`` (chunked prompts are never truncated);
  * satellite: `telemetry()` gains kv_block_stalls / parked_slots /
    prefill_chunks / pool_utilization;
  * `core.functional.pool_try_alloc` park/wake unit semantics.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from test_paged_pool import _check_conservation, _fresh_paged_state

from repro.core.functional import (
    make_block_pool,
    pool_free_count,
    pool_release,
    pool_try_alloc,
    woken_mask,
)
from repro.serving.engine_state import (
    chunked_prefill_token_fn,
    engine_round,
    make_paged_pool_model,
    paged_pool_admit_fn,
    paged_pool_token_fn,
    rid_token_fn,
)
from repro.serving.scheduler import ContinuousBatchingEngine, Request

DT = 0.25  # f32-exact virtual-time grid (see tests/test_megastep.py)


def _rid_step_fn(active):
    return np.array([r.rid * 1000 + len(r.out_tokens) for r in active],
                    np.int64)


_IDENT = lambda lg: lg.astype(np.int64)  # noqa: E731


# ------------------------------------ pool_try_alloc / park_state unit ------


def test_pool_try_alloc_park_and_wake():
    """A parked row's waiting-array bucket moves exactly when enough
    releases landed to cover its deficit — the TWA long-term wait at block
    granularity (wake = re-check hint, FCFS by cursor order)."""
    pool = make_block_pool(8)
    pool, ids, _, _ = pool_try_alloc(
        pool, jnp.asarray([6, 0], jnp.int32), 6,
        park=jnp.asarray([False, False]), deficit=jnp.asarray([0, 0]))
    assert int(pool_free_count(pool)) == 2
    # a row short 3 blocks (needs 5, 2 free) parks with deficit 3
    pool2, _, bkt, seq = pool_try_alloc(
        pool, jnp.asarray([0, 0], jnp.int32), 6,
        park=jnp.asarray([False, True]), deficit=jnp.asarray([0, 3]))
    assert int(pool_free_count(pool2)) == 2
    # 2 releases: not enough — the observed bucket must NOT move
    pool3 = pool_release(pool2, ids[:1, :2], jnp.asarray([True]))
    assert not bool(woken_mask(pool3.sema, seq[1:], bkt[1:])[0])
    # the 3rd release crosses the deficit — the bucket is poked
    pool4 = pool_release(pool3, ids[:1, 2:3], jnp.asarray([True]))
    assert bool(woken_mask(pool4.sema, seq[1:], bkt[1:])[0])


# ------------------------------------ chunked megastep ≡ host loop ----------


def _mk_chunked(clk, *, n_slots=4, kv_pool=(16, 4), chunked=(5, 9, 16),
                use_kernel=True, wrap=False, prompt_cap=32):
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots,
        tenants={"gold": 2.0, "bronze": 1.0}, use_kernel=use_kernel,
        clock=lambda: clk[0], kv_pool=kv_pool, chunked_prefill=chunked,
        prompt_cap=prompt_cap)
    if wrap:
        base = jnp.uint32((1 << 32) - 7)
        eng.qos = eng.qos._replace(
            ticket=jnp.full((2,), base), grant=jnp.full((2,), base),
            consumed=jnp.full((2,), base))
    return eng


def _workload(seed, n_req, deadline_frac):
    """Prompts up to 18 tokens against a 16×4 pool: first chunks of a
    5-token chunk size demand 1-2 blocks while lifetimes demand up to 7 —
    incremental takes, parks, and resumes all occur."""
    rng = np.random.default_rng(seed)
    names = ["gold", "bronze"]
    reqs = []
    for i in range(n_req):
        dl = DT * int(rng.integers(0, 20)) if rng.random() < deadline_frac \
            else None
        reqs.append(Request(
            rid=i, prompt=[1 + i % 7] * int(rng.integers(1, 19)),
            max_new_tokens=1 + int(rng.integers(0, 10)),
            tenant_id=names[int(rng.integers(0, 2))], deadline=dl))
    return reqs


def _compare_chunked_engines(seed, deadline_frac, wrap, *, use_kernel=True,
                             K=18, n_req=14):
    clk = [0.0]
    eh = _mk_chunked(clk, wrap=wrap, use_kernel=use_kernel)
    em = _mk_chunked(clk, wrap=wrap, use_kernel=use_kernel)
    rh = _workload(seed, n_req, deadline_frac)
    rm = _workload(seed, n_req, deadline_frac)
    eh.submit_batch(rh)
    em.submit_batch(rm)
    times = [k * DT for k in range(K)]
    for t in times:
        clk[0] = t
        eh.step(_IDENT)
    clk[0] = 0.0
    em.megastep(K, token_fn=rid_token_fn, nows=np.asarray(times, np.float32))
    for a, b in zip(rh, rm):
        tag = f"seed={seed} rid={a.rid}"
        assert a.out_tokens == b.out_tokens, (tag, a.out_tokens, b.out_tokens)
        assert a.admit_round == b.admit_round, (tag, a.admit_round,
                                                b.admit_round)
        assert a.expired == b.expired and a.preempted == b.preempted, tag
        assert a.expire_round == b.expire_round, tag
    for a, b in zip(rh, rm):  # prefill/park carry-state of survivors
        if a.slot is not None and not a.expired and a in eh.active.values():
            # past plen the cursor encodings differ (host pins at plen,
            # device reports plen+emitted) but both re-seed identically
            pl = len(a.prompt) or 1
            assert min(a.prefill_pos, pl) == min(b.prefill_pos, pl), \
                (seed, a.rid)
            assert a.parked == b.parked, (seed, a.rid)
            assert a.kv_blocks == b.kv_blocks, (seed, a.rid)
    for f in eh.qos._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(eh.qos, f)), np.asarray(getattr(em.qos, f)),
            err_msg=f"seed={seed}:{f}")
    assert eh._qos_free == em._qos_free
    assert eh._kv_free_blocks == em._kv_free_blocks, seed
    # the host block-semaphore mirror must equal the device pool semaphore
    # (same takes, posts, and waiting-array pokes ⇒ same park/wake rounds)
    dev = em._kv_state.pool.sema
    assert int(eh._kv_sema.ticket) == int(dev.ticket), seed
    assert int(eh._kv_sema.grant) == int(dev.grant), seed
    np.testing.assert_array_equal(np.asarray(eh._kv_sema.bucket_seq),
                                  np.asarray(dev.bucket_seq),
                                  err_msg=str(seed))
    assert eh.stats.admitted == em.stats.admitted
    assert eh.stats.preempted == em.stats.preempted
    assert eh.stats.kv_block_stalls == em.stats.kv_block_stalls, seed
    assert eh.stats.prefill_chunks == em.stats.prefill_chunks, seed


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.4]), st.booleans())
def test_chunked_megastep_equals_host_loop_property(seed, deadline_frac,
                                                    wrap):
    """ISSUE acceptance: chunked megastep(K) ≡ K chunked step() calls,
    round-for-round — token streams, admission/park/resume rounds,
    expiry/preemption, QoS state, free blocks, the block-semaphore
    waiting-array state, and the stall/chunk counters."""
    _compare_chunked_engines(seed, deadline_frac, wrap)


def test_chunked_queue_walk_mode_drives_same_streams():
    """The non-kernel host admission mode (TWA queue walk, lazily poked
    queues) co-schedules the same chunk phase: identical token streams and
    a fully-drained pool — admission ROUND timing may differ from the
    eager kernel path (the walk only re-examines poked queues), so the
    equality is stream-level, not round-level."""
    clk = [0.0]
    ew = _mk_chunked(clk, use_kernel=False)
    ek = _mk_chunked(clk, use_kernel=True)
    rw = _workload(11, 14, 0.0)
    rk = _workload(11, 14, 0.0)
    ew.submit_batch(rw)
    ek.submit_batch(rk)
    for k in range(80):
        clk[0] = k * DT
        ew.step(_IDENT)
        ek.step(_IDENT)
    assert ew.stats.finished == ek.stats.finished == len(rw)
    for a, b in zip(rw, rk):
        assert a.out_tokens == b.out_tokens, a.rid
    assert ew._kv_free_blocks == ek._kv_free_blocks == 16
    assert ew.stats.kv_block_stalls > 0  # parks exercised in walk mode too


# ------------------------------------ chunk-size invariance -----------------


def _attn_run(chunked, *, K=8, n_req=6, n_slots=4, prompt_len=23, vocab=40):
    NB, BS = 32, 4
    eng = ContinuousBatchingEngine(
        lambda a: None, lambda r: None, n_slots, tenants={"a": 1.0},
        clock=lambda: 0.0, kv_pool=(NB, BS, 16), prompt_cap=64,
        chunked_prefill=chunked)
    eng.megastep_model = make_paged_pool_model(
        jax.random.PRNGKey(0), vocab=vocab, d=16, num_blocks=NB,
        block_size=BS)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=list(rng.integers(1, vocab, prompt_len)),
                    max_new_tokens=6, tenant_id="a") for i in range(n_req)]
    eng.submit_batch(reqs)
    tok_fn = chunked_prefill_token_fn if chunked else paged_pool_token_fn
    adm_fn = None if chunked else paged_pool_admit_fn
    launches = 0
    while eng.stats.finished < n_req and launches < 120:
        eng.megastep(K, token_fn=tok_fn, admit_fn=adm_fn)
        launches += 1
    assert eng.stats.finished == n_req
    assert eng.telemetry()["kv_blocks_free"] == NB
    return eng, [r.out_tokens for r in reqs]


@settings(max_examples=3, deadline=None)
@given(st.sampled_from([(2, 5), (4, 16), (7, 7), (16, 64)]))
def test_chunk_size_invariance_property(chunked):
    """ISSUE satellite: chunked prefill (ANY chunk size, aligned or not)
    is bit-identical to one-shot prefill through the REAL pool-attention
    model — the KV a sequence decodes against is independent of how its
    prompt was chunked or which blocks it landed in."""
    _, one_shot = _attn_run(None)
    ec, streams = _attn_run(chunked)
    assert streams == one_shot, chunked
    assert ec.stats.prefill_chunks > 0


def test_chunked_serves_prompts_beyond_oneshot_table():
    """Long-prompt capability: prompts far longer than the one-shot
    in-graph prefill previously handled stream through megastep in chunks
    and decode correctly (same streams for two different chunk sizes)."""
    _, a = _attn_run((6, 12), prompt_len=49, n_req=4)
    _, b = _attn_run((16, 32), prompt_len=49, n_req=4)
    assert a == b
    assert all(len(t) == 6 for t in a)


# ------------------------------------ conservation under park/resume --------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1), st.booleans())
def test_block_conservation_chunked_property(seed, wrap):
    """ISSUE satellite: incremental allocation preserves the PR-4
    conservation invariant — free-queue ∪ live tables = {0..NB−1}, no
    block aliased into two live tables — at every round under random
    park/resume interleavings, incl. the block counters crossing 2³²;
    the workload fully drains (no deadlock at engine-round level)."""
    start = (1 << 32) - 5 if wrap else 0
    state, NB, BS = _fresh_paged_state(12, start=start, seed=seed)
    step = jax.jit(lambda s, now: engine_round(
        s, (), now, token_fn=rid_token_fn, block_size=BS,
        chunk=5, budget=9, commit=NB)[0])

    _check_conservation(state.kv, NB, "init")
    stalls = 0
    for k in range(96):
        state = step(state, k * DT)
        stalls = int(state.stalls)
        _check_conservation(state.kv, NB, f"round {k}")
    assert not bool(np.asarray(state.slots.busy).any())
    assert int(pool_free_count(state.kv.pool)) == NB
    assert stalls >= 0  # counter drained into the state (see no-deadlock test)


# ------------------------------------ no deadlock / FCFS resume -------------


def test_no_deadlock_under_saturation_and_fcfs_resume():
    """A pool an order of magnitude smaller than aggregate demand: every
    sequence still finishes (the headroom invariant keeps one slot always
    runnable), parks are actually exercised, and parked slots RESUME in
    Banker priority order (earliest admission first — strict FCFS, no
    overtaking among equal-tenant sequences)."""
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 4, tenants={"a": 1.0},
        use_kernel=True, clock=lambda: 0.0, kv_pool=(8, 4),
        chunked_prefill=(4, 8, 8), prompt_cap=32)  # watermark = whole pool
    reqs = [Request(rid=i, prompt=[1] * 14, max_new_tokens=10,
                    tenant_id="a") for i in range(6)]  # 6×6 blocks vs 8
    eng.submit_batch(reqs)
    for _ in range(400):
        eng.step(_IDENT)
        if eng.stats.finished == len(reqs):
            break
    assert eng.stats.finished == len(reqs), "deadlocked under saturation"
    assert eng.stats.kv_block_stalls > 0, "parks never exercised"
    assert eng.telemetry()["kv_blocks_free"] == 8
    assert all(len(r.out_tokens) == 10 for r in reqs)
    # FCFS resume: completion order == admission (ticket) order per tenant
    fins = sorted(reqs, key=lambda r: r.finish_t)
    assert [r.rid for r in fins] == sorted(r.rid for r in reqs)


def test_headroom_and_watermark_pipeline_admission():
    """Reserved headroom + commitment watermark: while a running long
    sequence still needs most of the pool, a newcomer is NOT admitted
    into its reserve; once the runner's remaining demand drains below the
    watermark the newcomer pipelines in mid-flight — and the headroom
    keeps the runner's tail blocks protected, so BOTH finish (nobody
    deadlocks, nobody is starved)."""
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 2, tenants={"a": 1.0},
        use_kernel=True, clock=lambda: 0.0, kv_pool=(8, 4),
        chunked_prefill=(4, 4), prompt_cap=32)  # default watermark: 4
    big = Request(rid=0, prompt=[1] * 8, max_new_tokens=20, tenant_id="a")
    eng.submit_batch([big])  # lifetime demand 7 > watermark: bootstraps
    eng.step(_IDENT)
    assert big.slot is not None  # over-watermark yet admitted (alone)
    late = Request(rid=1, prompt=[1] * 4, max_new_tokens=4, tenant_id="a")
    eng.submit_batch([late])
    eng.step(_IDENT)
    assert late.slot is None  # big's outstanding demand holds the gate
    admitted_mid_flight = False
    for _ in range(200):
        eng.step(_IDENT)
        if late.slot is not None and big.finish_t == 0.0:
            admitted_mid_flight = True  # pipelined into the drained slack
        if eng.stats.finished == 2:
            break
    assert eng.stats.finished == 2
    assert admitted_mid_flight  # commitment is pipelined, not up-front
    assert big.admit_round < late.admit_round  # FCFS at the gate held
    assert all(len(r.out_tokens) == r.max_new_tokens for r in (big, late))
    assert eng.telemetry()["kv_blocks_free"] == 8


# ------------------------------------ submit-time capacity ValueError -------


def test_submit_rejects_over_capacity_and_over_prompt_cap():
    """ISSUE satellite: a request whose prompt_len + max_new exceeds total
    pool capacity fails at submit with a clear ValueError (it would park
    forever otherwise); chunked prompts longer than prompt_cap are also
    rejected (never truncated)."""
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 2, tenants={"a": 1.0},
        use_kernel=True, clock=lambda: 0.0, kv_pool=(8, 4),
        chunked_prefill=(4, 8), prompt_cap=64)
    with pytest.raises(ValueError, match="stall forever"):
        eng.submit_batch([Request(rid=0, prompt=[1] * 20, max_new_tokens=20,
                                  tenant_id="a")])  # 40 tokens > 8×4
    with pytest.raises(ValueError, match="prompt_cap"):
        eng.submit_batch([Request(rid=1, prompt=[1] * 65, max_new_tokens=1,
                                  tenant_id="a")])
    # boundary: exactly pool capacity is fine
    eng.submit_batch([Request(rid=2, prompt=[1] * 16, max_new_tokens=16,
                              tenant_id="a")])
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(  # chunked needs the pool
            _rid_step_fn, lambda r: None, 2, tenants={"a": 1.0},
            chunked_prefill=(4, 8))
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(  # degenerate chunk/budget
            _rid_step_fn, lambda r: None, 2, tenants={"a": 1.0},
            kv_pool=(8, 4), chunked_prefill=(0, 8))
    # a token_fn whose static scatter window is narrower than the engine
    # chunk would silently drop chunk tails — rejected at launch
    from repro.serving.engine_state import make_chunked_prefill_token_fn
    with pytest.raises(ValueError, match="chunk window"):
        eng.megastep(1, token_fn=make_chunked_prefill_token_fn(2))


# ------------------------------------ telemetry gauges ----------------------


def test_telemetry_chunked_gauges():
    """ISSUE satellite: kv_block_stalls / parked_slots / prefill_chunks /
    pool_utilization ride next to the PR-4 block gauges and track the
    incremental lifecycle."""
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 2, tenants={"a": 1.0},
        use_kernel=True, clock=lambda: 0.0, kv_pool=(8, 4),
        chunked_prefill=(4, 8), prompt_cap=32)
    tel = eng.telemetry()
    for g in ("kv_block_stalls", "parked_slots", "prefill_chunks",
              "pool_utilization"):
        assert g in tel, g
    assert tel["pool_utilization"] == 0.0
    reqs = [Request(rid=i, prompt=[1] * 12, max_new_tokens=8,
                    tenant_id="a") for i in range(2)]
    eng.submit_batch(reqs)
    eng.step(_IDENT)
    tel = eng.telemetry()
    assert tel["prefill_chunks"] >= 1
    assert 0.0 < tel["pool_utilization"] <= 1.0
    # incremental reservations track written tokens, not worst case:
    # 2 slots × 1 first-chunk block, vs worst-case 2×5 blocks
    assert tel["kv_blocks_live"] <= 4
    while eng.stats.finished < 2:
        eng.step(_IDENT)
    tel = eng.telemetry()
    assert tel["pool_utilization"] == 0.0 and tel["kv_blocks_free"] == 8
    assert tel["parked_slots"] == 0
