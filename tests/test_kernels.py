"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis on the
semaphore kernel (per assignment: every kernel allclose against ref.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is an optional test dependency (pyproject `test` extra)
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import decode_attention_ref, mha_ref, sema_batch_ref
from repro.kernels.sema_batch import sema_batch

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ------------------------------------------------------------- flash fwd ----


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,hd,causal,window,bq,bk",
    [
        (2, 128, 4, 2, 64, True, 0, 64, 64),     # GQA 2:1
        (1, 256, 8, 8, 64, True, 0, 128, 64),    # MHA, rectangular blocks
        (2, 128, 4, 1, 64, True, 32, 64, 64),    # MQA + sliding window
        (1, 64, 2, 2, 128, False, 0, 64, 64),    # non-causal, hd=128
        (1, 192, 6, 3, 64, True, 0, 64, 64),     # non-pow2 heads
        (1, 128, 4, 4, 256, True, 0, 64, 64),    # gemma-like hd=256
    ],
)
def test_flash_attention_vs_ref(B, S, H, KV, hd, causal, window, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
    ref = mha_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_flash_attention_matches_model_path():
    """Kernel == the model's blockwise-attention production path."""
    from repro.models.layers import blockwise_attention

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, KV, hd = 2, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    out_k = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64,
                                interpret=True)
    out_m = blockwise_attention(q, k, v, pos, pos, kv_block=64)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m), atol=2e-5)


# ----------------------------------------------------------- decode attn ----


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,C,H,KV,hd,window,fill,bk",
    [
        (2, 256, 4, 2, 64, 0, 200, 128),
        (1, 512, 8, 1, 128, 128, 512, 128),   # MQA rolling window
        (3, 128, 6, 6, 64, 0, 60, 64),        # ragged (part-empty cache)
        (1, 96, 2, 2, 64, 0, 96, 32),         # non-pow2 capacity
    ],
)
def test_decode_attention_vs_ref(B, C, H, KV, hd, window, fill, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, C, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, C, KV, hd), dtype)
    kv_pos = jnp.where(jnp.arange(C)[None] < fill, jnp.arange(C)[None], -1)
    kv_pos = jnp.broadcast_to(kv_pos, (B, C)).astype(jnp.int32)
    q_pos = jnp.full((B,), fill, jnp.int32)
    out = decode_attention(q, k, v, kv_pos, q_pos, window=window, block_k=bk,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, kv_pos, q_pos, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_decode_rolling_buffer_positions():
    """Rolling cache: slots hold out-of-order positions; masking must follow
    pos, not slot index."""
    B, C, H, KV, hd = 1, 8, 2, 1, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, C, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, C, KV, hd), jnp.float32)
    # positions rolled: slot i holds position (i + 5) % 11, some beyond q_pos
    kv_pos = ((jnp.arange(C) + 5) % 11)[None].astype(jnp.int32)
    q_pos = jnp.array([7], jnp.int32)
    out = decode_attention(q, k, v, kv_pos, q_pos, block_k=8, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_pos, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ------------------------------------------------------------ sema batch ----


@pytest.mark.parametrize(
    "N,T,count,post_n,block_n",
    [(16, 64, 4, 3, 8), (100, 256, 20, 50, 32), (1024, 1024, 100, 200, 512),
     (7, 32, 0, 40, 8)],
)
def test_sema_batch_vs_ref(N, T, count, post_n, block_n):
    req = jax.random.bernoulli(jax.random.PRNGKey(2), 0.7, (N,))
    ticket = jnp.uint32(5)
    grant = jnp.uint32(5 + count)
    salt = jnp.uint32(0x1234)
    seq = jnp.arange(T, dtype=jnp.uint32)  # non-trivial initial sequences
    nt, ng, nseq, tk, adm, bkt, wok = sema_batch(
        ticket, grant, seq, req, jnp.uint32(post_n), salt,
        block_n=block_n, interpret=True,
    )
    ref = sema_batch_ref(ticket, grant, seq, req, jnp.uint32(post_n), salt)
    assert int(nt) == int(ref["ticket"]) and int(ng) == int(ref["grant"])
    np.testing.assert_array_equal(np.asarray(nseq), np.asarray(ref["bucket_seq"]))
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(ref["tickets"]))
    np.testing.assert_array_equal(np.asarray(adm), np.asarray(ref["admitted"]))
    np.testing.assert_array_equal(np.asarray(bkt), np.asarray(ref["bucket"]))
    np.testing.assert_array_equal(np.asarray(wok), np.asarray(ref["woken"]))


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 64),   # N
    st.integers(0, 16),   # count
    st.integers(0, 32),   # post_n
    st.integers(0, 2**32 - 1),  # salt
    st.floats(0.0, 1.0),  # request density
)
def test_sema_batch_property(N, count, post_n, salt, dens):
    """Kernel == oracle for arbitrary request patterns, and the TWA no-lost-
    wakeup invariant holds: every waiter whose ticket the post enabled is in
    the woken set (absent table-orbit aliasing, enforced by post_n < T)."""
    T = 64
    req = jax.random.bernoulli(jax.random.PRNGKey(salt % 1000), dens, (N,))
    nt, ng, nseq, tk, adm, bkt, wok = sema_batch(
        jnp.uint32(0), jnp.uint32(count), jnp.zeros((T,), jnp.uint32),
        req, jnp.uint32(post_n), jnp.uint32(salt), block_n=16, interpret=True,
    )
    ref = sema_batch_ref(jnp.uint32(0), jnp.uint32(count),
                         jnp.zeros((T,), jnp.uint32), req,
                         jnp.uint32(post_n), jnp.uint32(salt))
    np.testing.assert_array_equal(np.asarray(adm), np.asarray(ref["admitted"]))
    np.testing.assert_array_equal(np.asarray(wok), np.asarray(ref["woken"]))
    # no-lost-wakeup: enabled & waiting ⇒ woken
    tk_np = np.asarray(tk)
    waiting = np.asarray(req) & ~np.asarray(adm)
    enabled = (tk_np.astype(np.int64) >= count) & (tk_np.astype(np.int64) < count + post_n)
    assert np.all(~(waiting & enabled) | np.asarray(wok))
