"""L2 — functional batched semaphore (core.functional) vs a sequential oracle.

The batched take is defined to linearize requests in row order; these tests
check it against a literal sequential ticket-semaphore simulation, including
the TWAHash bucket notification semantics (woken_mask must cover every waiter
whose admission state could have changed — no lost wakeups, spurious wakes
allowed), plus hypothesis property tests over random take/post interleavings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:  # hypothesis is an optional test dependency (pyproject `test` extra)
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.functional import (
    bucket_index,
    make_multi_sema,
    make_sema,
    poll,
    post_batch,
    take_batch,
    take_batch_multi,
    post_batch_multi,
    woken_mask,
)


def test_take_batch_fifo_ranks():
    s = make_sema(count=3, table_size=64)
    req = jnp.array([True, True, False, True, True, True])
    s2, tickets, admitted, buckets = take_batch(s, req)
    # tickets: row order among requesters; non-requesters get placeholder rank
    np.testing.assert_array_equal(np.asarray(tickets), [0, 1, 2, 2, 3, 4])
    # grant=3 ⇒ exactly the first three requesters admitted (FCFS)
    np.testing.assert_array_equal(np.asarray(admitted), [1, 1, 0, 1, 0, 0])
    assert int(s2.ticket) == 5 and int(s2.grant) == 3


def test_post_then_poll_admits_in_order():
    s = make_sema(0, table_size=64)
    s, tickets, admitted, buckets = take_batch(s, jnp.ones(4, bool))
    assert not bool(admitted.any())
    s = post_batch(s, 2)
    adm = poll(s, tickets)
    np.testing.assert_array_equal(np.asarray(adm), [1, 1, 0, 0])
    s = post_batch(s, 2)
    np.testing.assert_array_equal(np.asarray(poll(s, tickets)), [1, 1, 1, 1])


def test_woken_mask_no_lost_wakeups():
    """Every waiter whose ticket was granted must see its bucket move."""
    s = make_sema(0, table_size=32)
    s, tickets, admitted, buckets = take_batch(s, jnp.ones(8, bool))
    observed = s.bucket_seq[buckets]  # waiters sample their bucket (KeyMonitor)
    s = post_batch(s, 5)
    woken = woken_mask(s, observed, buckets)
    granted = np.asarray(poll(s, tickets))
    # TWA guarantee: granted ⇒ woken (spurious wakes allowed, lost wakes not)
    assert np.all(~granted | np.asarray(woken))


def test_bucket_dispersal_stride17():
    """Adjacent tickets land 17 buckets apart (paper's ticket-aware hash)."""
    s = make_sema(0, table_size=1024)
    idx = np.asarray(bucket_index(s, jnp.arange(64, dtype=jnp.uint32)))
    d = np.diff(idx) % 1024
    assert np.all(d == 17)


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(st.booleans(), st.integers(0, 5)),  # (is_post?, n) per event
        min_size=1,
        max_size=30,
    ),
    st.integers(0, 4),  # initial count
)
def test_sequential_oracle_property(events, count):
    """Random interleaving of batched takes and posts matches a plain
    counting-semaphore oracle: the k-th requester (global FCFS order) is
    admitted iff k < grant at evaluation time; totals always conserve."""
    s = make_sema(count, table_size=64)
    oracle_tickets = 0
    oracle_grant = count
    all_tickets = []
    for is_post, n in events:
        if is_post:
            s = post_batch(s, n)
            oracle_grant += n
        else:
            req = jnp.ones(max(n, 0), bool)
            if n == 0:
                continue
            s, tk, adm, _ = take_batch(s, req)
            np.testing.assert_array_equal(
                np.asarray(tk), np.arange(oracle_tickets, oracle_tickets + n)
            )
            expect = (np.arange(oracle_tickets, oracle_tickets + n) < oracle_grant)
            np.testing.assert_array_equal(np.asarray(adm), expect)
            oracle_tickets += n
            all_tickets.extend(range(oracle_tickets - n, oracle_tickets))
        assert int(s.ticket) == oracle_tickets
        assert int(s.grant) == oracle_grant
    # final poll = oracle admission for every ticket ever issued
    if all_tickets:
        adm = np.asarray(poll(s, jnp.asarray(all_tickets, dtype=jnp.uint32)))
        np.testing.assert_array_equal(adm, np.asarray(all_tickets) < oracle_grant)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(1, 5),  # n semaphores (experts)
    st.lists(st.integers(0, 4), min_size=1, max_size=40),  # expert id per token
    st.integers(1, 6),  # capacity
)
def test_multi_sema_oracle(n_sema, ids, capacity):
    """Per-expert FCFS capacity admission == independent sequential counters."""
    ids = [i % n_sema for i in ids]
    st_ = make_multi_sema(jnp.full((n_sema,), capacity, jnp.uint32))
    st2, tickets, admitted = take_batch_multi(
        st_, jnp.asarray(ids, jnp.int32), jnp.ones(len(ids), bool)
    )
    counts = {e: 0 for e in range(n_sema)}
    for row, e in enumerate(ids):
        expect = counts[e] < capacity
        assert bool(admitted[row]) == expect, (row, e, counts)
        assert int(tickets[row]) == counts[e]  # ticket == expert buffer slot
        counts[e] += 1
    # post frees capacity per-expert
    st3 = post_batch_multi(st2, jnp.ones((n_sema,), jnp.uint32))
    st4, t2, adm2 = take_batch_multi(
        st3, jnp.asarray([0], jnp.int32), jnp.ones(1, bool)
    )
    assert bool(adm2[0]) == (counts[0] < capacity + 1)


def test_take_post_jit_roundtrip():
    """The functional semaphore composes under jit/scan (in-graph use)."""

    @jax.jit
    def run(s):
        def body(s, _):
            s, tk, adm, _ = take_batch(s, jnp.ones(3, bool))
            s = post_batch(s, 2)
            return s, adm.sum()
        return jax.lax.scan(body, s, None, length=5)

    s, adms = run(make_sema(2, table_size=64))
    # 3 takes vs 2 posts per step ⇒ deficit grows by 1; admission at
    # take-time sees the *pre-post* grant (waiters poll later, FIFO):
    assert int(s.ticket) == 15 and int(s.grant) == 12
    np.testing.assert_array_equal(np.asarray(adms), [2, 1, 0, 0, 0])
    # every issued ticket below the final grant is (by now) admitted — FIFO
    adm = np.asarray(poll(s, jnp.arange(15, dtype=jnp.uint32)))
    np.testing.assert_array_equal(adm, np.arange(15) < 12)
