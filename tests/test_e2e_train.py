"""End-to-end driver tests: train loop through the full substrate stack
(pipeline → step → checkpoint → resume) and the serving driver on a real
reduced model."""

from __future__ import annotations

import numpy as np

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_train_loop_learns_and_resumes(tmp_path):
    ck = str(tmp_path / "ck")
    losses = train_main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "14", "--batch", "4",
        "--seq", "64", "--ckpt-dir", ck, "--ckpt-every", "7", "--lr", "5e-3",
        "--log-every", "50",
    ])
    assert len(losses) == 14
    assert np.mean(losses[-4:]) < np.mean(losses[:4]), "loss did not improve"
    # resume continues from step 14 (checkpointed at the end) for 4 more
    more = train_main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "18", "--batch", "4",
        "--seq", "64", "--ckpt-dir", ck, "--resume", "--log-every", "50",
    ])
    assert len(more) == 4  # only the new steps ran


def test_train_moe_arch_runs():
    losses = train_main([
        "--arch", "granite-moe-3b-a800m", "--smoke", "--steps", "6",
        "--batch", "4", "--seq", "32", "--log-every", "50",
    ])
    assert np.isfinite(losses).all()


def test_serve_driver_fcfs():
    eng = serve_main(["--arch", "qwen2-0.5b", "--requests", "10", "--slots", "3",
                      "--prompt-len", "4", "--max-new", "5"])
    assert eng.stats.finished == 10
    # FCFS admission across the run
    reqs = sorted(
        [r for slot_r in [eng.active.values()] for r in slot_r], key=lambda r: r.rid
    )
    assert eng.telemetry()["queue_depth"] == 0
