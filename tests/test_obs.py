"""Observability layer (PR 6) — the in-scan telemetry ring and `repro.obs`.

  * tentpole property: ``megastep(K)``'s TelemetryRing drains to records
    BIT-IDENTICAL to the concatenation of K host ``step()`` samples —
    every probe including the waiting-array occupancy histogram and the
    three grant−ticket backlogs — across kernel-QoS, block-paged, and
    chunked-prefill modes, deadline preemption, park/resume, and 2³²
    counter wrap (hypothesis);
  * acceptance: a megastep with the ring enabled remains ONE host sync
    (``stats.host_syncs``), and ``telemetry()`` is pure host-side reads
    (never bumps the counter);
  * satellite: ``pool_utilization`` is always present — ``None`` for
    dense engines, a float for paged ones (the documented contract);
  * request lifecycle clocks (submit/first/last/finish) agree between the
    two serving paths, so per-tenant SLO summaries match;
  * `repro.obs` units: LogHistogram quantiles vs a full-sample numpy
    oracle, RollingMedian vs a naive window median, sink fan-out.
"""

from __future__ import annotations

import json
import math

import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.obs import (
    CallbackSink,
    EngineObs,
    FlightRecorder,
    JsonlSink,
    LogHistogram,
    RollingMedian,
    StdoutSink,
    TenantSLO,
    aggregate,
    build_spans,
    to_perfetto,
)
from repro.serving.engine_state import rid_token_fn
from repro.serving.events import (
    EV_COW,
    EV_PARK,
    EV_PREFIX_ATTACH,
    EV_RESUME,
    TERMINAL_EVENTS,
)
from repro.serving.scheduler import ContinuousBatchingEngine, Request

import test_chunked_prefill as tcp
import test_megastep as tms
import test_paged_pool as tpp

DT = tms.DT
_IDENT = tms._IDENT

_SAMPLE_KEYS = {
    "round", "clock", "admits", "expires", "preempts", "tokens",
    "prefill_tokens", "prefill_chunks", "prefill_pending", "gate_stalls",
    "parked", "backlog", "active", "slot_free", "kv_free", "kv_pokes",
    "health", "credit", "poke_dead", "kv_wait_hist",
    # PR 9 sharing gauges — zero on non-sharing engines, still mirrored
    # bit-identically host step() vs megastep ring
    "prefix_hits", "blocks_shared", "cow_copies",
    # PR 10 in-scan trace-event table: list of [kind, uid, slot, arg] in
    # the canonical segment order — the `==` below IS the bit-identical
    # megastep-vs-host event-stream property
    "events",
}

_CLOCK_FIELDS = ("submit_clock", "first_tok_clock", "last_tok_clock",
                 "finish_clock")


def _drive_pair(eh, em, rh, rm, K, *, obs_pair=None):
    """Drive identical workloads through K host steps vs one megastep(K);
    return (host samples, mega samples)."""
    eh.submit_batch(rh)
    em.submit_batch(rm)
    times = [k * DT for k in range(K)]
    host_samples = []
    for t in times:
        eh._clock_box[0] = t
        eh.step(_IDENT)
        host_samples.extend(eh.telemetry()["last_samples"])
    em._clock_box[0] = 0.0
    em.megastep(K, token_fn=rid_token_fn,
                nows=np.asarray(times, np.float32))
    mega_samples = em.telemetry()["last_samples"]
    return host_samples, mega_samples


def _mk_pair(mk, **kw):
    """Two identical engines on independent virtual clocks; the clock box
    is stashed on the engine so _drive_pair can advance them separately."""
    out = []
    for _ in range(2):
        clk = [0.0]
        eng = mk(clk, **kw)
        eng._clock_box = clk
        out.append(eng)
    return out


def _assert_bit_identical(hs, ms, K, tag=""):
    assert len(hs) == K and len(ms) == K, (tag, len(hs), len(ms))
    for k, (a, b) in enumerate(zip(hs, ms)):
        assert set(a) == set(b) == _SAMPLE_KEYS, (tag, k)
        for key in _SAMPLE_KEYS:
            assert a[key] == b[key], (tag, k, key, a[key], b[key])


def _assert_clocks_equal(rh, rm, tag=""):
    for a, b in zip(rh, rm):
        for f in _CLOCK_FIELDS:
            assert getattr(a, f) == getattr(b, f), \
                (tag, a.rid, f, getattr(a, f), getattr(b, f))


# ------------------------------------------- tentpole: ring ≡ K snapshots ---


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.5]),
       st.booleans())
def test_telemetry_ring_equals_host_snapshots_qos(seed, frac, wrap):
    """Kernel-QoS mode: megastep(K) ring ≡ K step() samples, bit-identical
    (incl. per-tenant credit vectors and poke-window slack through wrap)."""
    K, n_req = 12, 18
    eh, em = _mk_pair(tms._mk_engine, wrap=wrap)
    hs, ms = _drive_pair(eh, em, tms._workload(seed, n_req, frac),
                         tms._workload(seed, n_req, frac), K)
    _assert_bit_identical(hs, ms, K, f"qos seed={seed}")
    assert eh.stats.host_syncs == K and em.stats.host_syncs == 1


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.5]),
       st.booleans())
def test_telemetry_ring_equals_host_snapshots_paged(seed, frac, wrap):
    """Block-paged mode: the ring's kv_free / kv_pokes / gate_stalls
    probes mirror the host block-semaphore counters exactly — the up-front
    host mirror advances its ticket at the gate and posts (with
    waiting-array pokes) at completion, exactly like the device pool."""
    K, n_req = 14, 16
    eh, em = _mk_pair(tpp._mk_engine, kv_pool=(16, 4), wrap=wrap)
    rh = tpp._workload(seed, n_req, frac)
    rm = tpp._workload(seed, n_req, frac)
    hs, ms = _drive_pair(eh, em, rh, rm, K)
    _assert_bit_identical(hs, ms, K, f"paged seed={seed}")
    _assert_clocks_equal(rh, rm, f"paged seed={seed}")
    assert em.stats.host_syncs == 1


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.5]),
       st.booleans())
def test_telemetry_ring_equals_host_snapshots_chunked(seed, frac, wrap):
    """Chunked-prefill mode: prefill_tokens/chunks/pending, parked, and
    the waiting-array occupancy histogram (the paper's long-term-wait
    observable) stay bit-identical through park/resume cycles."""
    K, n_req = 18, 14
    eh, em = _mk_pair(tcp._mk_chunked, wrap=wrap)
    rh = tcp._workload(seed, n_req, frac)
    rm = tcp._workload(seed, n_req, frac)
    hs, ms = _drive_pair(eh, em, rh, rm, K)
    _assert_bit_identical(hs, ms, K, f"chunked seed={seed}")
    _assert_clocks_equal(rh, rm, f"chunked seed={seed}")
    # parks occurred somewhere in the run → the occupancy histogram is a
    # live probe, not structurally zero
    if any(s["parked"] for s in hs):
        assert any(sum(s["kv_wait_hist"]) > 0 for s in hs)


def test_ring_probes_reflect_waiting_array():
    """Deterministic spot-check: when slots park on the block semaphore,
    the ring's kv_wait_hist counts exactly the parked slots' buckets and
    kv_pokes moves when releases poke the array."""
    clk = [0.0]
    eng = tcp._mk_chunked(clk)
    reqs = [Request(rid=i, prompt=[2] * 17, max_new_tokens=6,
                    tenant_id=["gold", "bronze"][i % 2])
            for i in range(8)]
    eng.submit_batch(reqs)
    K = 20
    times = np.asarray([k * DT for k in range(K)], np.float32)
    eng.megastep(K, token_fn=rid_token_fn, nows=times)
    samples = eng.telemetry()["last_samples"]
    assert len(samples) == K
    for s in samples:
        assert sum(s["kv_wait_hist"]) == s["parked"]
    assert any(s["parked"] > 0 for s in samples)  # parks actually occurred
    assert samples[-1]["kv_pokes"] > 0            # releases poked buckets


# --------------------------------------------- acceptance: sync accounting --


def test_megastep_with_ring_is_one_host_sync():
    """ISSUE acceptance: enabling the ring adds no host sync — megastep(K)
    stays at host_syncs == 1 and the drained samples ride that sync; a
    `telemetry()` call (pure host reads) never bumps the counter."""
    clk = [0.0]
    eng = tms._mk_engine(clk)
    eng.submit_batch(tms._workload(3, 12, 0.0))
    eng.megastep(10, token_fn=rid_token_fn,
                 nows=np.asarray([k * DT for k in range(10)], np.float32))
    assert eng.stats.host_syncs == 1
    before = eng.stats.host_syncs
    tel = eng.telemetry()
    assert len(tel["last_samples"]) == 10
    assert eng.stats.host_syncs == before  # telemetry is sync-free
    assert tel["stats"]["host_syncs"] == before


def test_host_step_records_one_sample_per_round():
    clk = [0.0]
    eng = tms._mk_engine(clk)
    eng.submit_batch(tms._workload(7, 6, 0.0))
    seen = []
    for k in range(8):
        clk[0] = k * DT
        eng.step(_IDENT)
        samples = eng.telemetry()["last_samples"]
        assert len(samples) == 1 and samples[0]["round"] == k
        seen.append(samples[0])
    assert [s["round"] for s in seen] == list(range(8))
    assert eng.stats.host_syncs == 8


# ------------------------------------------ satellite: pool_utilization -----


def test_pool_utilization_contract():
    """`telemetry()['pool_utilization']` is ALWAYS present: None for dense
    engines (no pool), float for paged ones — callers branch on the value,
    never on key presence (the documented contract)."""
    clk = [0.0]
    dense = tms._mk_engine(clk)
    tel = dense.telemetry()
    assert "pool_utilization" in tel and tel["pool_utilization"] is None
    assert "kv_blocks_free" not in tel  # pool gauges stay paged-only

    paged = tpp._mk_engine(clk, kv_pool=(16, 4))
    tel = paged.telemetry()
    assert isinstance(tel["pool_utilization"], float)
    assert tel["pool_utilization"] == 0.0  # fresh pool: nothing written

    # non-QoS dense engine takes the same contract path
    basic = ContinuousBatchingEngine(tms._rid_step_fn, lambda r: None, 2)
    assert basic.telemetry()["pool_utilization"] is None


# --------------------------------------------------- SLO / EngineObs layer --


def test_slo_summary_host_equals_megastep():
    """Attach an EngineObs to both serving paths: identical sample streams
    and lifecycle clocks ⇒ identical per-tenant SLO summaries."""
    obs_h = EngineObs(ttft_target=2.0)
    obs_m = EngineObs(ttft_target=2.0)
    eh, em = _mk_pair(tms._mk_engine)
    eh._obs, em._obs = obs_h, obs_m
    K = 12
    rh = tms._workload(5, 18, 0.5)
    rm = tms._workload(5, 18, 0.5)
    hs, ms = _drive_pair(eh, em, rh, rm, K)
    _assert_bit_identical(hs, ms, K, "slo")
    _assert_clocks_equal(rh, rm, "slo")
    sh, sm = obs_h.summary(), obs_m.summary()
    assert sh["rounds"] == sm["rounds"] == K
    # resolved requests may differ only by the still-running tail; compare
    # the tenants both saw
    for t in set(sh["tenants"]) & set(sm["tenants"]):
        assert sh["tenants"][t] == sm["tenants"][t], t
    assert eh.telemetry()["slo"] == sh
    assert em.telemetry()["slo"] == sm


def test_engine_obs_ttft_tpot_math():
    """TTFT/TPOT definitions, straight from the lifecycle clocks."""

    class R:  # minimal duck-typed resolved request
        tenant_id = "gold"
        out_tokens = [1, 2, 3, 4, 5]
        expired = False
        preempted = False
        submit_clock = 1.0
        first_tok_clock = 3.0
        last_tok_clock = 5.0

    obs = EngineObs(ttft_target=2.5)
    obs.record_request(R())
    s = obs.summary()["tenants"]["gold"]
    assert s["finished"] == 1 and s["expired"] == 0
    assert abs(s["ttft"]["p50"] - 2.0) / 2.0 <= 0.011  # ±resolution
    assert abs(s["tpot"]["p50"] - 0.5) / 0.5 <= 0.011  # (5-3)/(5-1)
    assert s["attainment"] == 1.0

    class Miss(R):
        first_tok_clock = 9.0
        last_tok_clock = 9.0
        out_tokens = [1]

    obs.record_request(Miss())
    s = obs.summary()["tenants"]["gold"]
    assert s["attainment"] == 0.5  # TTFT 8.0 > target 2.5

    class Dead(R):
        expired = True
        preempted = True

    obs.record_request(Dead())
    s = obs.summary()["tenants"]["gold"]
    assert s["expired"] == 1 and s["preempted"] == 1
    assert s["attainment"] == 1 / 3
    table = obs.render_table()
    assert "gold" in table and "attain" in table


# ------------------------------------------------------- obs unit pieces ----


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.05, 0.01]))
def test_log_histogram_vs_numpy_oracle(seed, res):
    """Any quantile of the streaming histogram is within ±resolution
    relative error of the full-sample numpy percentile."""
    rng = np.random.default_rng(seed)
    # lognormal: heavy tail spanning several decades — the regime the
    # geometric buckets exist for
    xs = rng.lognormal(mean=0.0, sigma=2.0, size=2000)
    h = LogHistogram(resolution=res)
    for x in xs:
        h.add(float(x))
    for q in (0.5, 0.9, 0.99, 0.999):
        est = h.quantile(q)
        true = float(np.quantile(xs, q))
        assert est <= h.max and est >= h.min
        assert abs(est - true) / true <= res + 1e-9, (q, est, true)
    assert h.count == len(xs)
    assert abs(h.mean - xs.mean()) / xs.mean() < 1e-9
    assert h.quantile(0.0) == xs.min() and h.quantile(1.0) == xs.max()


def test_log_histogram_merge():
    a, b = LogHistogram(), LogHistogram()
    xs = [0.1, 1.0, 2.0]
    ys = [5.0, 50.0]
    for x in xs:
        a.add(x)
    for y in ys:
        b.add(y)
    a.merge(b)
    assert a.count == 5 and a.max == 50.0 and a.min == 0.1
    c = LogHistogram()
    for v in xs + ys:
        c.add(v)
    assert a.quantile(0.5) == c.quantile(0.5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 12))
def test_rolling_median_vs_naive(seed, window):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=60)
    rm = RollingMedian(window)
    for i, x in enumerate(xs):
        got = rm.push(float(x))
        want = float(np.median(xs[max(0, i + 1 - window):i + 1]))
        assert got == want, (i, got, want)
    assert rm.value == want
    rm.reset()
    assert math.isnan(rm.value)


def test_sinks_fan_out(tmp_path):
    path = tmp_path / "trace.jsonl"
    got = []
    obs = EngineObs([JsonlSink(str(path)), CallbackSink(got.append),
                     StdoutSink(prefix="# ")], smooth_window=3)
    for k in range(5):
        obs.record_round({"round": k, "tokens": k % 2, "active": 1,
                          "kv_free": 10 - k, "prefill_tokens": 0})
    obs.close()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == len(got) == 5
    assert [r["round"] for r in lines] == list(range(5))
    # rolling-median companion trace rides each record
    assert lines[-1]["smoothed"]["kv_free"] == 7  # median(8, 7, 6)
    assert got[0]["smoothed"]["tokens"] == 0  # first value echoes


def test_callback_sink_filter():
    got = []
    sink = CallbackSink(got.append, filter=lambda r: r["tokens"] > 0)
    sink.emit({"tokens": 0})
    sink.emit({"tokens": 3})
    assert got == [{"tokens": 3}] and sink.emitted == 1


# ------------------------------------------- PR 10: trace completeness ------


def _drain_engine(eng, clk, *, max_megasteps=20, K=12):
    """Megasteps until every submitted request is resolved (virtual time
    keeps advancing); returns total rounds driven."""
    total = (eng.stats.finished + eng.stats.expired
             + len(eng.backlog) + len(eng.active)
             + sum(len(q) for q in (eng._tenant_queues or [])))
    rounds = 0
    for _ in range(max_megasteps):
        nows = np.asarray([(rounds + k) * DT for k in range(K)], np.float32)
        eng.megastep(K, token_fn=rid_token_fn, nows=nows)
        rounds += K
        if eng.stats.finished + eng.stats.expired >= total:
            break
    assert eng.stats.finished + eng.stats.expired >= total, "did not drain"
    return rounds


def _assert_wellformed(spans, reqs, tag=""):
    """Exactly ONE closed, well-formed span per submitted request — no
    orphans, no duplicates, exactly one terminal event, non-negative
    critical-path categories that never exceed the total."""
    assert set(spans) == {r.rid for r in reqs}, tag
    for rid, sp in spans.items():
        assert sp["terminal"] is not None, (tag, rid)
        terminals = [e for e in sp["events"]
                     if e["kind"] in TERMINAL_EVENTS]
        assert len(terminals) == 1, (tag, rid)
        bd = sp["breakdown"]
        for k in ("queue", "prefill", "park", "decode", "migration"):
            assert bd[k] >= 0, (tag, rid, k)
            assert bd[k] <= bd["total"] + 1e-6, (tag, rid, k)


def test_trace_spans_park_resume():
    """Chunked-prefill path: long prompts park on the block TWA mid
    prefill; every request still yields one span, and the parks surface
    as PARK/RESUME event pairs with park time in the breakdown."""
    clk = [0.0]
    eng = tcp._mk_chunked(clk)
    reqs = [Request(rid=i, prompt=[2] * 17, max_new_tokens=4,
                    tenant_id=["gold", "bronze"][i % 2])
            for i in range(8)]
    eng.submit_batch(reqs)
    _drain_engine(eng, clk)
    spans = build_spans(eng._trace)
    _assert_wellformed(spans, reqs, "park_resume")
    kinds = [e["kind"] for sp in spans.values() for e in sp["events"]]
    assert EV_PARK in kinds and EV_RESUME in kinds
    assert any(sp["breakdown"]["park"] > 0 for sp in spans.values())
    assert any(s["name"] == "park" for sp in spans.values()
               for s in sp["segments"])


def test_trace_spans_deadline_preemption():
    """Tight deadlines: queue tombstones (EXPIRE) and mid-decode
    preemptions (PREEMPT) both close their spans — exactly one terminal
    each, nothing orphaned."""
    clk = [0.0]
    eng = tms._mk_engine(clk)
    reqs = tms._workload(11, 18, 0.8)
    eng.submit_batch(reqs)
    _drain_engine(eng, clk)
    spans = build_spans(eng._trace)
    _assert_wellformed(spans, reqs, "preempt")
    terms = {sp["terminal"] for sp in spans.values()}
    assert "FINISH" in terms
    assert terms & {"PREEMPT", "EXPIRE"}, terms


def test_trace_spans_prefix_attach():
    """Prefix-sharing path: cached-prefix admissions emit PREFIX_ATTACH
    (and tail collisions later COW) without disturbing span shape."""
    import test_prefix_cache as tpc

    clk = [0.0]
    eng = tpc._mk_share(clk)
    reqs = tpc._share_workload(5, 14, 0.0)
    eng.submit_batch(reqs)
    _drain_engine(eng, clk)
    spans = build_spans(eng._trace)
    _assert_wellformed(spans, reqs, "prefix")
    kinds = [e["kind"] for sp in spans.values() for e in sp["events"]]
    assert EV_PREFIX_ATTACH in kinds
    att = [e for sp in spans.values() for e in sp["events"]
           if e["kind"] == EV_PREFIX_ATTACH]
    assert all(e["arg"] > 0 for e in att)  # arg = covered tokens


def test_trace_spans_ticket_wrap():
    """Spans stay complete when every TWA counter straddles 2³² during
    the run (the wrap-safe `_sdist` property at the trace level)."""
    clk = [0.0]
    eng = tcp._mk_chunked(clk, wrap=True)
    reqs = tcp._workload(3, 10, 0.0)
    eng.submit_batch(reqs)
    _drain_engine(eng, clk)
    _assert_wellformed(build_spans(eng._trace), reqs, "wrap")


def test_trace_host_step_equals_megastep_spans():
    """The host step() trace and the megastep ring-drain trace build
    IDENTICAL span sets (same terminals, same event kinds per uid) —
    the bit-identity property lifted to the span level."""
    eh, em = _mk_pair(tcp._mk_chunked)
    rh = tcp._workload(9, 12, 0.5)
    rm = tcp._workload(9, 12, 0.5)
    hs, ms = _drive_pair(eh, em, rh, rm, 24)
    sph = build_spans(eh._trace)
    spm = build_spans(em._trace)
    assert set(sph) == set(spm)
    for rid in sph:
        a, b = sph[rid], spm[rid]
        assert a["terminal"] == b["terminal"], rid
        assert [e["kind"] for e in a["events"]] == \
            [e["kind"] for e in b["events"]], rid
        assert a["breakdown"] == b["breakdown"], rid


def test_trace_cluster_migration_and_flight():
    """ISSUE acceptance: a cluster run with one REPLICA_KILL produces a
    stitched span per surviving request — migrated ones carrying a
    ``migration`` segment and BOTH replica indices — plus a
    flight-recorder bundle cut from the dead replica."""
    from repro.resilience.faults import REPLICA_KILL, FaultEvent, FaultPlan
    from repro.serving.router import toy_cluster, toy_workload

    plan = FaultPlan(seed=0, events=(
        FaultEvent(round=1, kind=REPLICA_KILL, arg=0, delta=2),))
    rt = toy_cluster(2, seed=3, plan=plan,
                     obs=lambda: EngineObs(
                         flight=FlightRecorder(capacity=16)))
    reqs = toy_workload(10, seed=5)
    rt.submit_batch(reqs)
    rep = rt.run(max_rounds=80)
    assert rep["stats"]["migrated"] > 0, "plan produced no migration"

    spans = rt.cluster_spans()
    surviving = [r.rid for r in reqs if r.rid in rt.completed]
    assert set(spans) == {r.rid for r in reqs}
    for rid in surviving:
        sp = spans[rid]
        assert sp["terminal"] == "FINISH", rid
        assert len([e for e in sp["events"]
                    if e["kind"] in TERMINAL_EVENTS]) == 1, rid
    migrated = [sp for sp in spans.values() if sp["migrations"] > 0]
    assert migrated
    for sp in migrated:
        assert any(s["name"] == "migration" for s in sp["segments"])
        assert sp["breakdown"]["migration"] > 0
        if sp["terminal"] == "FINISH":
            assert len(sp["replicas"]) >= 2, sp["uid"]

    dead = [r for r in rt.replicas if not r.alive]
    assert dead
    bundles = dead[0].eng._obs.flight.bundles
    assert any(b["reason"] == "replica_reaped" for b in bundles)
    b = [b for b in bundles if b["reason"] == "replica_reaped"][0]
    assert b["samples"] and isinstance(b["health"]["flags"], list)

    # fleet aggregation over the per-replica recorders
    fleet = aggregate([r.eng._obs for r in rt.replicas],
                      router=rt.fabric_telemetry())
    assert fleet["cluster"]["finished"] == len(surviving)
    assert fleet["fabric"]["migrations"] == rep["stats"]["migrated"]
    assert fleet["fabric"]["migration_latency"]["count"] > 0


def test_perfetto_export_format():
    """Chrome-trace JSON: every slice is a complete ``ph:"X"`` event with
    µs timestamps, metadata rows name pids/tids, and the whole thing
    round-trips through json — the chrome://tracing contract."""
    clk = [0.0]
    eng = tcp._mk_chunked(clk)
    reqs = tcp._workload(2, 8, 0.3)
    eng.submit_batch(reqs)
    _drain_engine(eng, clk)
    doc = to_perfetto(build_spans(eng._trace))
    doc2 = json.loads(json.dumps(doc))
    evs = doc2["traceEvents"]
    assert evs
    for e in evs:
        assert e["ph"] in ("X", "i", "M")
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert names <= {"queue", "prefill", "park", "decode", "migration"}


def test_engine_telemetry_trace_key():
    """`telemetry()['trace']` surfaces the span summary on BOTH serving
    paths, and host-step event ingestion matches the sample stream."""
    clk = [0.0]
    eng = tcp._mk_chunked(clk)
    eng.submit_batch(tcp._workload(4, 6, 0.0))
    k = 0
    while eng.stats.finished + eng.stats.expired < 6 and k < 200:
        clk[0] = k * DT
        eng.step(_IDENT)
        k += 1
    tr = eng.telemetry()["trace"]
    assert tr["spans"] == 6 and tr["complete"] == 6
    assert set(tr["critical_path"]) == {"queue", "prefill", "park",
                                        "decode", "migration"}
    assert tr["events"] > 0 and tr["dropped"] == 0


# --------------------------------- PR 10: mergeable histograms / fleet SLO --


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.05, 0.01]))
def test_log_histogram_merge_equals_combined_stream(seed, res):
    """Satellite property: merge(a, b) reports EXACTLY the quantiles of
    one histogram fed the concatenated stream — bucket-wise addition is
    lossless, so fleet aggregation pays zero extra quantile error."""
    rng = np.random.default_rng(seed)
    xs = rng.lognormal(0.0, 2.0, rng.integers(1, 200))
    ys = rng.lognormal(1.0, 1.0, rng.integers(1, 200))
    a, b, c = (LogHistogram(resolution=res) for _ in range(3))
    for x in xs:
        a.add(float(x))
    for y in ys:
        b.add(float(y))
    for v in list(xs) + list(ys):
        c.add(float(v))
    a.merge(b)
    assert a.count == c.count and a.max == c.max and a.min == c.min
    assert math.isclose(a.sum, c.sum, rel_tol=1e-12)
    for q in (0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0):
        assert a.quantile(q) == c.quantile(q), (q, a.quantile(q))


def test_tenant_slo_merge():
    a = TenantSLO(ttft_target=5.0)
    b = TenantSLO(ttft_target=5.0)
    a.record(n_tokens=3, expired=False, preempted=False, submit_clock=0.0,
             first_tok_clock=1.0, last_tok_clock=2.0)
    b.record(n_tokens=2, expired=False, preempted=False, submit_clock=0.0,
             first_tok_clock=9.0, last_tok_clock=9.5)
    b.record(n_tokens=0, expired=True, preempted=False, submit_clock=0.0,
             first_tok_clock=None, last_tok_clock=None)
    a.merge(b)
    s = a.summary()
    assert s["submitted"] == 3 and s["finished"] == 2 and s["expired"] == 1
    assert s["tokens"] == 5 and s["attainment"] == 1 / 3
    assert s["ttft"]["count"] == 2
    try:
        a.merge(TenantSLO(ttft_target=1.0))
        assert False, "target mismatch must raise"
    except ValueError:
        pass


def test_flight_recorder_edge_trigger():
    """One bundle per NEW sentinel bit — a persistently sick engine does
    not flood the bundle ring; explicit dump() always cuts one."""
    fr = FlightRecorder(capacity=4)
    fr.observe_round({"round": 0, "clock": 0.0, "health": 0})
    fr.observe_round({"round": 1, "clock": 0.5, "health": 1})
    fr.observe_round({"round": 2, "clock": 1.0, "health": 1})  # same bit
    fr.observe_round({"round": 3, "clock": 1.5, "health": 3})  # new bit
    assert [b["reason"] for b in fr.bundles] == ["sentinel", "sentinel"]
    assert fr.bundles[1]["extra"]["new_bits"] == 2
    fr.dump("manual", extra={"k": 1})
    assert fr.bundles[-1]["reason"] == "manual"
    assert len(fr.bundles[-1]["samples"]) == 4  # bounded window
    assert fr.summary()["bundles"] == 3


def test_engine_obs_health_flags_surfaced():
    """Satellite: the health bitmask is decoded to named flags in the
    summary and on sink records (single authoritative table in
    serving.sentinels)."""
    from repro.serving.sentinels import HEALTH_BITS

    got = []
    obs = EngineObs([CallbackSink(got.append)],
                    flight=FlightRecorder(capacity=2))
    bit = HEALTH_BITS["slot_conserve"]
    obs.record_round({"round": 0, "clock": 0.0, "health": bit})
    s = obs.summary()
    assert s["health"]["flags"] == ["slot_conserve"]
    assert got[0]["health_flags"] == ["slot_conserve"]
    assert obs.flight.bundles[0]["health"]["flags"] == ["slot_conserve"]
