"""Paper-fidelity tests for the L1 (host-thread) semaphores — all listings.

Covered claims:
  * counting-semaphore safety: never more than `count` threads inside;
  * liveness / no lost wakeups under heavy take/post churn (all waiting modes);
  * FIFO (first-come-first-enabled) admission for the ticket-based kinds —
    the paper's central QoI property (pthread-like baseline is *not* FIFO);
  * post(n) enables exactly n waiters;
  * benaphore fast-path in TWA post never skips a needed wake;
  * queue-depth telemetry (grant/ticket distance) monotonicity;
  * 64-bit wrap-around distance arithmetic.
"""

from __future__ import annotations

import threading
import time

import pytest

from repro.core import SEMAPHORE_KINDS
from repro.core.ticket_semaphore import _dist
from repro.core.twa_semaphore import TWASemaphore, WaitingArray

# Parking/futex-style kinds are safe under the GIL; pure-spin variants also
# terminate (pause() releases the GIL) but are slow, so stress counts differ.
KINDS = {
    "ticket-spin": lambda c=0: SEMAPHORE_KINDS["ticket"](c, waiting="spin"),
    "ticket-broadcast": lambda c=0: SEMAPHORE_KINDS["ticket"](c, waiting="broadcast"),
    "twa-spin": lambda c=0: SEMAPHORE_KINDS["twa"](c, waiting="spin"),
    "twa-futex": lambda c=0: SEMAPHORE_KINDS["twa"](c, waiting="futex"),
    "twa-chains": lambda c=0: SEMAPHORE_KINDS["twa-chains"](c),
    "twa-channels": lambda c=0: SEMAPHORE_KINDS["twa-channels"](c),
    "twa-v3": lambda c=0: SEMAPHORE_KINDS["twa-v3"](c),
    "pthread": lambda c=0: SEMAPHORE_KINDS["pthread"](c),
}
FIFO_KINDS = [k for k in KINDS if k != "pthread"]
SLOW = {"ticket-spin", "twa-spin"}  # GIL-polling: keep iteration counts low


@pytest.mark.parametrize("kind", list(KINDS))
def test_mutual_exclusion_and_liveness(kind):
    """count=1 semaphore used as a lock by N threads: the shared counter
    increments race-free and every thread finishes (no lost wakeups)."""
    sem = KINDS[kind](1)
    n_threads, iters = (4, 50) if kind in SLOW else (8, 200)
    shared = {"x": 0, "max_inside": 0, "inside": 0}
    guard = threading.Lock()

    def worker():
        for _ in range(iters):
            sem.take()
            with guard:
                shared["inside"] += 1
                shared["max_inside"] = max(shared["max_inside"], shared["inside"])
            x = shared["x"]
            shared["x"] = x + 1
            with guard:
                shared["inside"] -= 1
            sem.post()

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
        assert not t.is_alive(), f"{kind}: lost wakeup / deadlock"
    assert shared["x"] == n_threads * iters
    assert shared["max_inside"] == 1


@pytest.mark.parametrize("kind", list(KINDS))
def test_counting_capacity(kind):
    """count=K: at most K concurrently inside the critical region."""
    K = 3
    sem = KINDS[kind](K)
    n_threads, iters = (6, 20) if kind in SLOW else (10, 60)
    inside = {"now": 0, "max": 0}
    guard = threading.Lock()

    def worker():
        for _ in range(iters):
            sem.take()
            with guard:
                inside["now"] += 1
                inside["max"] = max(inside["max"], inside["now"])
            time.sleep(0)
            with guard:
                inside["now"] -= 1
            sem.post()

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
        assert not t.is_alive()
    assert 1 <= inside["max"] <= K


@pytest.mark.parametrize("kind", FIFO_KINDS)
def test_fifo_admission(kind):
    """Ticket-based semaphores admit in arrival (ticket) order.  We serialize
    arrivals (so ticket order is known), then release one permit at a time
    and observe completion order == arrival order."""
    sem = KINDS[kind](0)
    order = []
    guard = threading.Lock()
    started = threading.Semaphore(0)

    def waiter(i):
        started.release()
        sem.take()
        with guard:
            order.append(i)

    ts = []
    for i in range(8):
        t = threading.Thread(target=waiter, args=(i,))
        ts.append(t)
        t.start()
        started.acquire()
        # Wait until the thread has actually taken its ticket (ticket counter
        # advanced) so arrival order is deterministic.
        deadline = time.time() + 10
        while sem.ticket.load() != i + 1 and time.time() < deadline:
            time.sleep(0.001)
        assert sem.ticket.load() == i + 1

    for i in range(8):
        sem.post()
        deadline = time.time() + 30
        while len(order) != i + 1 and time.time() < deadline:
            time.sleep(0.001)
        assert order == list(range(i + 1)), f"{kind}: admission out of order: {order}"
    for t in ts:
        t.join(timeout=30)
        assert not t.is_alive()


@pytest.mark.parametrize("kind", ["twa-futex", "twa-chains", "twa-channels", "pthread"])
def test_post_n_enables_n(kind):
    sem = KINDS[kind](0)
    done = threading.Semaphore(0)

    def waiter():
        sem.take()
        done.release()

    ts = [threading.Thread(target=waiter) for _ in range(6)]
    for t in ts:
        t.start()
    time.sleep(0.05)
    sem.post(4)
    for _ in range(4):
        assert done.acquire(timeout=30)
    time.sleep(0.1)
    assert not done.acquire(blocking=False), "post(4) enabled a 5th waiter"
    sem.post(2)
    for _ in range(2):
        assert done.acquire(timeout=30)
    for t in ts:
        t.join(timeout=30)


def test_benaphore_fast_path_equivalence():
    """TWA post with and without the racy fast path admits identically."""
    for fast in (True, False):
        sem = TWASemaphore(0, waiting="futex", post_fast_path=fast)
        results = []
        ts = [threading.Thread(target=lambda: (sem.take(), results.append(1)))
              for _ in range(5)]
        for t in ts:
            t.start()
        time.sleep(0.05)
        sem.post(5)
        for t in ts:
            t.join(timeout=30)
            assert not t.is_alive(), f"fast_path={fast} lost a wakeup"
        assert len(results) == 5


def test_private_waiting_array_and_collisions():
    """A 1-bucket array forces every waiter onto one bucket (max collisions):
    correctness must hold (collisions are a performance concern only)."""
    arr = WaitingArray(table_size=1)
    sem = TWASemaphore(0, waiting="futex", array=arr)
    done = threading.Semaphore(0)
    ts = [threading.Thread(target=lambda: (sem.take(), done.release())) for _ in range(6)]
    for t in ts:
        t.start()
    time.sleep(0.05)
    for _ in range(6):
        sem.post()
    for _ in range(6):
        assert done.acquire(timeout=30)
    for t in ts:
        t.join(timeout=10)


def test_queue_depth_telemetry():
    sem = TWASemaphore(2, waiting="futex")
    assert sem.available() == 2 and sem.queue_depth() == 0
    sem.take()
    sem.take()
    assert sem.available() == 0
    t = threading.Thread(target=sem.take)
    t.start()
    deadline = time.time() + 10
    while sem.queue_depth() != 1 and time.time() < deadline:
        time.sleep(0.001)
    assert sem.queue_depth() == 1  # grant/ticket distance = free telemetry
    sem.post()
    t.join(timeout=10)
    assert not t.is_alive()


def test_wraparound_distance():
    """64-bit modular distance: grant just past 2^64 still compares correctly."""
    near = (1 << 64) - 2
    assert _dist(1, near) == 3  # grant wrapped to 1, ticket at 2^64-2
    assert _dist(near, 1) == -3
    sem = TWASemaphore(0, waiting="futex")
    sem.ticket.store(near)
    sem.grant.store(near)
    sem.post(3)
    sem.take()  # ticket 2^64-2 vs grant 1 (wrapped): distance 3 > 0 → pass
    assert sem.available() == 2
