"""Substrate tests: data pipeline, checkpointing, coordinator, elastic
re-sharding, serving scheduler, gradient compression."""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import BoundedBuffer, DataLoader, SyntheticLM
from repro.optim.compression import BLOCK, compress_psum, init_residuals
from repro.runtime.coordinator import Coordinator, DistributedTicketLease, KVStore
from repro.serving.scheduler import ContinuousBatchingEngine, Request


# ------------------------------------------------------------------ data ----


def test_synthetic_deterministic():
    src = SyntheticLM(vocab=512, seq_len=64, seed=3)
    a = src.sample(42)
    b = src.sample(42)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shifted-by-one labels
    np.testing.assert_array_equal(a["tokens"][1:], a["labels"][:-1])


def test_bounded_buffer_fifo_and_backpressure():
    buf = BoundedBuffer(depth=4)
    for i in range(4):
        buf.put(i)
    bp = buf.backpressure()
    assert bp["items_ready"] == 4
    got = [buf.get() for _ in range(4)]
    assert got == [0, 1, 2, 3]  # FIFO through the TWA semaphores

    # producer blocks at depth, unblocks on get
    buf2 = BoundedBuffer(depth=1)
    buf2.put("a")
    t = threading.Thread(target=buf2.put, args=("b",))
    t.start()
    time.sleep(0.05)
    assert t.is_alive()  # blocked on `free`
    assert buf2.get() == "a"
    t.join(timeout=10)
    assert buf2.get() == "b"


def test_loader_resume_determinism():
    """Same start_step ⇒ same batches regardless of worker count (FIFO
    buffer + deterministic per-index sampling)."""
    src = SyntheticLM(vocab=128, seq_len=16, seed=1)

    def first_batches(n_workers, start_step, n=3):
        dl = DataLoader(src, 4, n_workers=n_workers, depth=2, start_step=start_step)
        it = iter(dl)
        out = [next(it)["tokens"].copy() for _ in range(n)]
        dl.stop()
        return out

    a = first_batches(1, 5)
    b = first_batches(3, 5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_loader_host_sharding_disjoint():
    src = SyntheticLM(vocab=128, seq_len=16, seed=1)
    dl0 = DataLoader(src, 2, n_workers=1, host_id=0, n_hosts=2)
    dl1 = DataLoader(src, 2, n_workers=1, host_id=1, n_hosts=2)
    b0 = next(iter(dl0))["tokens"]
    b1 = next(iter(dl1))["tokens"]
    dl0.stop(), dl1.stop()
    assert not np.array_equal(b0, b1)


# ------------------------------------------------------------ checkpoint ----


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    ck.save(3, tree, blocking=True)
    ck.save(7, jax.tree.map(lambda x: x + 1, tree), blocking=True)
    assert ck.complete_steps() == [3, 7]
    restored, step = ck.restore(tree)
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]) + 1)
    assert restored["b"]["c"].dtype == jnp.bfloat16
    # torn checkpoint (tmp dir) is invisible
    (tmp_path / "step_000000099.tmp").mkdir()
    assert ck.latest_step() == 7


def test_checkpoint_gc_keeps_newest(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.complete_steps() == [3, 4]


def test_checkpoint_multihost_commit(tmp_path):
    """Finalize waits for every host's commit marker (simulated hosts)."""
    tree = {"x": jnp.ones((2,))}
    h0 = CheckpointManager(str(tmp_path), host_id=0, expected_hosts=2)
    h1 = CheckpointManager(str(tmp_path), host_id=1, expected_hosts=2)
    t = threading.Thread(target=h0.save, args=(5, tree), kwargs={"blocking": True})
    t.start()
    time.sleep(0.1)
    assert h0.complete_steps() == []  # host 1 not committed yet
    h1.save(5, tree, blocking=True)
    t.join(timeout=30)
    assert h0.complete_steps() == [5]


def test_emergency_sync_save(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    ck.save_sync(11, {"x": jnp.ones((3,))})
    assert ck.latest_step() == 11


# ------------------------------------------------------------ coordinator ---


def test_lease_fifo_and_queue_depth():
    kv = KVStore()
    lease = DistributedTicketLease(kv, "ckpt", capacity=1)
    order = []

    def worker(i):
        lease.acquire()
        order.append(i)
        time.sleep(0.01)
        lease.release()

    ts = []
    for i in range(4):
        t = threading.Thread(target=worker, args=(i,))
        t.start()
        time.sleep(0.02)  # serialize ticket issuance
        ts.append(t)
    for t in ts:
        t.join(timeout=30)
    assert order == [0, 1, 2, 3]  # FCFS across "hosts"
    assert lease.queue_depth() == 0


def test_failure_detection_and_barrier():
    c = Coordinator(heartbeat_timeout=0.2)
    for h in (0, 1, 2):
        c.join(h)
    c.heartbeat(0, 1, 0.1)
    c.heartbeat(1, 1, 0.1)
    c.heartbeat(2, 1, 0.1)
    assert c.detect_failures() == []
    time.sleep(0.3)
    c.heartbeat(0, 2, 0.1)
    c.heartbeat(1, 2, 0.1)  # host 2 silent
    dead = c.detect_failures()
    assert dead == [2]
    assert c.alive_hosts() == [0, 1]
    # failure-aware barrier completes with survivors only
    done = []
    t0 = threading.Thread(target=lambda: done.append(c.barrier(0, "g1")))
    t1 = threading.Thread(target=lambda: done.append(c.barrier(1, "g1")))
    t0.start(), t1.start()
    t0.join(timeout=15), t1.join(timeout=15)
    assert done == [True, True]


def test_straggler_detection():
    c = Coordinator()
    for h in range(4):
        c.join(h)
    for _ in range(5):
        for h in range(4):
            c.heartbeat(h, 1, 0.1 if h != 3 else 0.5)
    assert c.stragglers() == [3]


# ----------------------------------------------------------- compression ----


def test_compression_ef_residual_correctness():
    """Single-shard compress_psum must reconstruct g up to block quantization,
    and the residual must carry exactly the quantization error."""
    import jax

    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)), jnp.float32)
    r0 = jnp.zeros_like(g)

    def f(g, r):
        return compress_psum(g, r, "pod", 1)

    from repro import compat
    out, res = jax.jit(
        compat.shard_map(f, mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),) * 2,
                         out_specs=(jax.sharding.PartitionSpec(),) * 2)
    )(g, r0)
    np.testing.assert_allclose(np.asarray(out + res), np.asarray(g), atol=1e-5)
    # quantization error bounded by scale = blockmax/127
    blocks = np.abs(np.asarray(g)).reshape(-1, BLOCK) if g.size % BLOCK == 0 else None
    assert float(jnp.max(jnp.abs(res))) <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6


def test_compression_unbiased_over_time():
    """Error feedback: Σ_t compressed_t ≈ Σ_t g_t (noise does not accumulate)."""
    rng = np.random.default_rng(1)
    mesh = jax.make_mesh((1,), ("pod",))
    P = jax.sharding.PartitionSpec

    from repro import compat

    @jax.jit
    def step(g, r):
        return compat.shard_map(lambda g, r: compress_psum(g, r, "pod", 1),
                                mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))(g, r)

    r = jnp.zeros((512,), jnp.float32)
    total_true = np.zeros(512)
    total_comp = np.zeros(512)
    for t in range(30):
        g = jnp.asarray(rng.normal(size=(512,)) * (1 + t % 3), jnp.float32)
        out, r = step(g, r)
        total_true += np.asarray(g)
        total_comp += np.asarray(out)
    # cumulative drift bounded by one quantization step, not 30
    assert np.max(np.abs(total_true - total_comp)) < np.abs(total_true).max() * 0.02 + 0.1


# ---------------------------------------------------------------- serving ---


def _toy_engine(n_slots=2, use_kernel=False):
    """Engine over a fake model: next token = len(out_tokens)."""

    def step_fn(active_reqs):
        return np.arange(len(active_reqs))

    def prefill_fn(req):
        pass

    return ContinuousBatchingEngine(step_fn, prefill_fn, n_slots, use_kernel=use_kernel)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_engine_fcfs_admission(use_kernel):
    eng = _toy_engine(n_slots=2, use_kernel=use_kernel)
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=2) for i in range(6)]
    eng.submit_batch(reqs)
    admit_order = []
    for _ in range(20):
        eng.step(lambda lg: lg[:, None].argmax(1) if hasattr(lg, "ndim") else lg)
        for slot, r in eng.active.items():
            if r.rid not in admit_order:
                admit_order.append(r.rid)
        if eng.stats.finished == 6:
            break
    assert eng.stats.finished == 6
    # FCFS: admission order == submission order (tickets are ordered)
    assert admit_order == sorted(admit_order)


def test_engine_backlog_skipping():
    """TWA property: with a deep backlog, un-poked requests are not
    re-examined."""
    eng = _toy_engine(n_slots=2)
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=3) for i in range(40)]
    eng.submit_batch(reqs)
    for _ in range(100):
        eng.step(lambda lg: np.zeros(len(lg), np.int64))
        if eng.stats.finished == 40:
            break
    assert eng.stats.finished == 40
    st = eng.stats
    # the scheduler should have skipped far more backlog entries than it
    # scanned (the anti-global-spinning effect)
    assert st.backlog_skipped > st.backlog_scans
    tel = eng.telemetry()
    assert tel["backlog"] == 0 and tel["active"] == 0
