"""Per-architecture smoke tests (assignment: every arch instantiates a
REDUCED same-family config and runs forward/train + serve steps on CPU with
shape and finiteness asserts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config, shapes_for
from repro.models.transformer import (
    decode_step,
    init_caches,
    init_params,
    prefill,
    train_loss,
)


def _batch_for(cfg, B, S):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.float32)
        batch["labels"] = jnp.ones((B, S + cfg.n_patches), jnp.int32)
    elif cfg.frontend == "audio":
        batch = {"frame_embeds": jnp.ones((B, S, cfg.d_model), jnp.float32),
                 "labels": jnp.ones((B, S), jnp.int32)}
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the assigned dimensions verbatim."""
    cfg = get_config(arch)
    expected = {
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "xlstm-350m": (24, 1024, None, None, None, 50304),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, None, 49155),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    }[arch]
    L, d, H, KV, dff, V = expected
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.vocab == V
    if H is not None:
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
    if dff is not None:
        assert cfg.d_ff == dff
    if arch == "deepseek-moe-16b":
        assert cfg.n_experts == 64 and cfg.top_k == 6 and cfg.n_shared == 2
        assert cfg.d_expert == 1408
    if arch == "granite-moe-3b-a800m":
        assert cfg.n_experts == 40 and cfg.top_k == 8 and cfg.d_expert == 512


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """Reduced config: one fwd/train step, output shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    loss, metrics = jax.jit(lambda p, b: train_loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    assert float(metrics["tokens"]) > 0
    # one SGD-flavoured step decreases loss on a repeated batch (some step
    # size must work — recurrent cells have touchier curvature)
    grads = jax.grad(lambda p: train_loss(p, cfg, batch)[0])(params)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in jax.tree.leaves(grads)), arch
    improved = False
    for lr in (0.5, 0.1, 0.02):
        params2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        loss2, _ = train_loss(params2, cfg, batch)
        if float(loss2) < float(loss):
            improved = True
            break
    assert improved, f"{arch}: not trainable at any probe step size"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Prefill+decode(t) == prefill over the longer prefix (cache exactness),
    token by token for 3 steps."""
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # capacity-based MoE admission is batch-size dependent by design
        # (FCFS overflow); for the exactness check give it headroom so no
        # token drops in either path.
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, S, extra = 2, 16, 3
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (B, S + extra), 0, cfg.vocab)
    patch = cfg.n_patches if cfg.frontend == "vision" else 0

    def batch_prefix(n):
        b = _batch_for(cfg, B, n)
        if cfg.frontend == "audio":
            emb = jnp.zeros((B, n, cfg.d_model), jnp.float32)
            emb = emb.at[..., 0].set(toks[:, :n].astype(jnp.float32) / cfg.vocab)
            b["frame_embeds"] = emb
        else:
            b["tokens"] = toks[:, :n]
        return b

    if cfg.frontend == "audio":
        # decode over audio tokens uses the embed table — compare decode
        # against itself for determinism instead of prefill equality
        caches = init_caches(cfg, B, S + extra + patch, jnp.float32)
        logits, caches = prefill(params, cfg, batch_prefix(S), caches)
        pos = jnp.full((B, 1), S, jnp.int32)
        l1, c1 = decode_step(params, cfg, toks[:, S:S + 1], pos, caches)
        l2, _ = decode_step(params, cfg, toks[:, S:S + 1], pos, caches)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))
        assert np.all(np.isfinite(np.asarray(l1)))
        return

    caches = init_caches(cfg, B, S + extra + patch, jnp.float32)
    logits, caches = prefill(params, cfg, batch_prefix(S), caches)
    for t in range(extra):
        pos = jnp.full((B, 1), S + t + patch, jnp.int32)
        logits_dec, caches = decode_step(params, cfg, toks[:, S + t:S + t + 1], pos, caches)
        caches_ref = init_caches(cfg, B, S + extra + patch, jnp.float32)
        logits_ref, _ = prefill(params, cfg, batch_prefix(S + t + 1), caches_ref)
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_ref), atol=2e-3, rtol=2e-3,
        )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shape_assignment(arch):
    shapes = [s.name for s in shapes_for(arch)]
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
    subq = arch in ("xlstm-350m", "recurrentgemma-9b", "gemma3-1b")
    assert ("long_500k" in shapes) == subq
