"""Device-resident decode megastep (serving/engine_state + scheduler
.megastep) vs the host-loop oracle, plus the PR-3 satellites:

  * property: K-round ``megastep(K)`` emits the SAME token streams,
    admission rounds/order, and expiry set as K sequential ``step()``
    calls under identical arrivals/deadlines/tenant mixes — including
    per-tenant ticket sequences wrapping 2³²;
  * deadline-aware decode preemption on BOTH paths: an expired running
    sequence is tombstoned, its slot reclaimed and re-granted to the next
    live ticket in FCFS order;
  * `kernels.qos_admission.qos_round_scan` (batch-of-rounds entry) ==
    K sequential `functional_qos.qos_round` calls, bit-exact;
  * compile-cache hits: the power-of-two backlog padding in
    `kernels.ops.qos_round` keeps steady-state serving on ONE compiled
    executable across distinct backlog lengths;
  * telemetry: ``queue_depth`` reflects the live QoS backlog (regression:
    it read the unused global semaphore and reported 0 while thousands
    queued).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:  # hypothesis is an optional test dependency (pyproject `test` extra)
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.serving.engine_state import (
    make_paged_attn_model,
    paged_attn_admit_fn,
    paged_attn_token_fn,
    rid_token_fn,
)
from repro.serving.scheduler import ContinuousBatchingEngine, Request

WEIGHTS = {"gold": 4.0, "silver": 2.0, "bronze": 1.0}
DT = 0.25  # virtual-time grid: exact in float32, so host f64 and in-graph
#            f32 deadline comparisons can never disagree at the boundary


def _rid_step_fn(active):
    """Host-loop counterpart of `engine_state.rid_token_fn`: logits ARE the
    deterministic request-identity token (sampled by identity)."""
    return np.array([r.rid * 1000 + len(r.out_tokens) for r in active],
                    np.int64)


_IDENT = lambda lg: lg.astype(np.int64)  # noqa: E731


def _mk_engine(clk, *, use_kernel=True, n_slots=4, weights=WEIGHTS,
               wrap=False):
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots, tenants=dict(weights),
        use_kernel=use_kernel, clock=lambda: clk[0])
    if wrap:  # per-tenant ticket sequences straddle 2³² during the run
        base = jnp.uint32((1 << 32) - 7)
        S = len(weights)
        eng.qos = eng.qos._replace(
            ticket=jnp.full((S,), base), grant=jnp.full((S,), base),
            consumed=jnp.full((S,), base))
    return eng


def _workload(seed: int, n_req: int, deadline_frac: float):
    rng = np.random.default_rng(seed)
    names = list(WEIGHTS)
    reqs = []
    for i in range(n_req):
        dl = None
        if rng.random() < deadline_frac:
            dl = DT * int(rng.integers(0, 16))  # on the f32-exact grid
        reqs.append(Request(
            rid=i, prompt=[1 + int(rng.integers(0, 9))],
            max_new_tokens=1 + int(rng.integers(0, 3)),
            tenant_id=names[int(rng.integers(0, len(names)))],
            deadline=dl))
    return reqs


def _compare_engines(seed, deadline_frac, wrap, K=12, n_req=18):
    """Drive identical workloads through the host step-loop and ONE
    megastep(K); every observable must match round-for-round."""
    clk = [0.0]
    eh = _mk_engine(clk, wrap=wrap)
    em = _mk_engine(clk, wrap=wrap)
    rh = _workload(seed, n_req, deadline_frac)
    rm = _workload(seed, n_req, deadline_frac)
    clk[0] = 0.0
    eh.submit_batch(rh)
    em.submit_batch(rm)

    times = [k * DT for k in range(K)]
    for t in times:  # host loop: K syncs at virtual times t_k
        clk[0] = t
        eh.step(_IDENT)
    clk[0] = 0.0  # megastep launches at the epoch; nows carry the times
    em.megastep(K, token_fn=rid_token_fn, nows=np.asarray(times, np.float32))

    for a, b in zip(rh, rm):
        tag = f"seed={seed} rid={a.rid}"
        assert a.out_tokens == b.out_tokens, (tag, a.out_tokens, b.out_tokens)
        assert a.admit_round == b.admit_round, (tag, a.admit_round,
                                                b.admit_round)
        assert a.expired == b.expired, tag
        assert a.preempted == b.preempted, tag
        assert a.expire_round == b.expire_round, (tag, a.expire_round,
                                                  b.expire_round)
    assert eh.stats.finished == em.stats.finished
    assert eh.stats.expired == em.stats.expired
    assert eh.stats.preempted == em.stats.preempted
    assert eh.stats.admitted == em.stats.admitted
    # the QoS semaphore state itself must evolve bit-identically
    for f in eh.qos._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(eh.qos, f)), np.asarray(getattr(em.qos, f)),
            err_msg=f"seed={seed}:{f}")
    assert eh._qos_free == em._qos_free
    # K host syncs collapsed to one launch+drain
    assert eh.stats.host_syncs == K and em.stats.host_syncs == 1


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**32 - 1),            # workload seed
       st.sampled_from([0.0, 0.4, 0.8]),     # deadline density
       st.booleans())                        # tickets wrap 2³²
def test_megastep_equals_host_loop_property(seed, deadline_frac, wrap):
    """ISSUE acceptance: megastep(K) ≡ K sequential step() calls — token
    streams, admission rounds, expiry/preemption sets, the QoS state, and
    the free pool, bit-for-bit, with and without 2³² ticket wrap."""
    _compare_engines(seed, deadline_frac, wrap)


def test_megastep_multi_launch_continuity():
    """Sequences spanning several megasteps (max_new > K): slot state is
    rebuilt from host bookkeeping each launch and streams stay identical
    to the host loop."""
    clk = [0.0]
    eh = _mk_engine(clk, n_slots=2, weights={"a": 1.0})
    em = _mk_engine(clk, n_slots=2, weights={"a": 1.0})
    rh = [Request(rid=i, prompt=[1], max_new_tokens=7, tenant_id="a")
          for i in range(5)]
    rm = [Request(rid=i, prompt=[1], max_new_tokens=7, tenant_id="a")
          for i in range(5)]
    eh.submit_batch(rh)
    em.submit_batch(rm)
    for _ in range(21):
        eh.step(_IDENT)
    for _ in range(7):  # 3 launches of K=7 ≡ 21 steps
        em.megastep(3, token_fn=rid_token_fn)
    for a, b in zip(rh, rm):
        assert a.out_tokens == b.out_tokens, (a.rid, a.out_tokens,
                                              b.out_tokens)
        assert a.admit_round == b.admit_round, a.rid
    assert eh.stats.finished == em.stats.finished == 5
    assert em.stats.host_syncs == 7


# ------------------------------------------------- decode preemption --------


def _preempt_engine(clk, mode):
    return ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots=1, tenants={"a": 1.0},
        use_kernel=(mode == "kernel"), clock=lambda: clk[0])


def _drive(eng, mode, clk):
    if mode == "mega":
        eng.megastep(1, token_fn=rid_token_fn, nows=[clk[0]])
    else:
        eng.step(_IDENT)


def _preemption_scenario(mode):
    """A running hog whose deadline passes mid-decode is tombstoned; its
    slot is re-granted to the next live ticket in FCFS order (the earliest
    waiter, not a later one)."""
    clk = [0.0]
    eng = _preempt_engine(clk, mode)
    hog = Request(rid=0, prompt=[1], max_new_tokens=100, tenant_id="a",
                  deadline=2.0)
    nxt = Request(rid=1, prompt=[1], max_new_tokens=2, tenant_id="a")
    later = Request(rid=2, prompt=[1], max_new_tokens=2, tenant_id="a")
    eng.submit_batch([hog, nxt, later])
    for _ in range(3):
        _drive(eng, mode, clk)
        clk[0] += DT
    assert hog.slot == 0 and len(hog.out_tokens) == 3 and not hog.expired
    clk[0] = 2.5  # hog's deadline passes while it is DECODING
    for _ in range(4):
        _drive(eng, mode, clk)
        clk[0] += DT
    assert hog.preempted and hog.expired and hog.done_event.is_set()
    assert len(hog.out_tokens) == 3  # no tokens after preemption
    assert eng.stats.preempted == 1 and eng.stats.expired == 1
    # FCFS re-grant: the freed slot went to `nxt` (earlier ticket), and
    # only after nxt finished could `later` run
    assert nxt.out_tokens == [1000, 1001]
    assert nxt.admit_round < later.admit_round or later.admit_round == -1
    assert eng.tenant_expired["a"] == 1


def test_preempted_slot_regranted_fcfs_host():
    """Satellite: host (non-kernel) step() path."""
    _preemption_scenario("host")


def test_preempted_slot_regranted_fcfs_kernel():
    _preemption_scenario("kernel")


def test_preempted_slot_regranted_fcfs_megastep():
    _preemption_scenario("mega")


def test_preemption_within_single_megastep():
    """The in-graph case: deadline passes at round k INSIDE one megastep —
    the slot is reclaimed mid-scan and the next ticket admitted without
    any host sync."""
    clk = [0.0]
    eng = _preempt_engine(clk, "mega")
    hog = Request(rid=0, prompt=[1], max_new_tokens=100, tenant_id="a",
                  deadline=1.0)
    nxt = Request(rid=1, prompt=[1], max_new_tokens=3, tenant_id="a")
    eng.submit_batch([hog, nxt])
    nows = np.asarray([0.0, 0.5, 1.0, 1.25, 1.5, 1.75], np.float32)
    eng.megastep(6, token_fn=rid_token_fn, nows=nows)
    assert hog.preempted and len(hog.out_tokens) == 2  # rounds 0, 1
    assert nxt.out_tokens == [1000, 1001, 1002]  # admitted at round 2
    assert hog.expire_round == 2 and nxt.admit_round == 2
    assert eng.stats.host_syncs == 1


def test_megastep_drains_deadline_heap():
    """Regression: a non-kernel QoS engine served exclusively via megastep
    must not retain resolved deadline Requests in the host expiry heap
    forever (only the host step() path pops it)."""
    clk = [0.0]
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots=4, tenants={"a": 1.0},
        use_kernel=False, clock=lambda: clk[0])
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=2, tenant_id="a",
                    deadline=100.0) for i in range(50)]
    eng.submit_batch(reqs)
    assert len(eng._deadline_heap) == 50
    while eng.stats.finished < 50:
        eng.megastep(4, token_fn=rid_token_fn)
    assert eng.stats.finished == 50
    assert len(eng._deadline_heap) == 0


# ------------------------------------------------ batch-of-rounds scan ------


def test_qos_round_scan_matches_sequential_ref():
    """`kernels.qos_admission.qos_round_scan` (K fused rounds under one
    lax.scan, slot-release feedback folded per round) is bit-identical to
    K sequential functional rounds (`ref.qos_round_scan_ref`)."""
    from repro.admission.functional_qos import make_qos, qos_take
    from repro.kernels.qos_admission import qos_round_scan
    from repro.kernels.ref import qos_round_scan_ref

    S, N, K = 3, 24, 3
    rng = np.random.default_rng(11)
    state = make_qos([3.0, 2.0, 1.0], table_size=64)
    ids = jnp.asarray(rng.integers(0, S, N), jnp.int32)
    state, tks, _, _ = qos_take(state, ids, jnp.ones(N, bool))
    alive = jnp.asarray(rng.random(N) > 0.2)
    dls = jnp.asarray(np.where(rng.random(N) > 0.5, rng.uniform(0, 2, N),
                               np.inf), jnp.float32)
    nows = np.asarray([0.0, 0.8, 1.6], np.float32)
    rel = np.asarray([0, 2, 1], np.int32)

    ref = qos_round_scan_ref(state, ids, tks, alive, dls, nows, 4, rel, 8)
    st2, ar, er, fr = qos_round_scan(state, ids, tks, alive, dls,
                                     jnp.asarray(nows), 4, jnp.asarray(rel),
                                     max_units=8, block_n=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(ar),
                                  np.asarray(ref["admit_round"]))
    np.testing.assert_array_equal(np.asarray(er),
                                  np.asarray(ref["expire_round"]))
    assert int(fr) == int(ref["free"])
    for f in state._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(st2, f)),
            np.asarray(getattr(ref["state"], f)), err_msg=f)


# ----------------------------------------------- compile-cache (pow2) -------


def test_qos_round_compile_cache_hits():
    """Satellite: the power-of-two backlog padding keeps every backlog
    length ≤ block_n on ONE compiled executable (the steady-state serving
    case), and a draining multi-block backlog on O(log N) shapes — no
    retrace per distinct length."""
    from repro.admission.functional_qos import make_qos, qos_take
    from repro.kernels import ops
    from repro.kernels.qos_admission import qos_round_fused

    def round_n(n):
        st = make_qos([1.0, 2.0], table_size=64)
        ii = np.zeros(n, np.int32)
        st, tt, _, _ = qos_take(st, jnp.asarray(ii), jnp.ones(n, bool))
        st2, adm, exp, _ = ops.qos_round(
            st, ii, np.asarray(tt), np.ones(n, bool),
            np.full(n, np.inf, np.float32), 0.0, 2, max_units=4)
        assert adm.shape == (n,) and exp.shape == (n,)

    round_n(5)  # warm the steady-state executable
    before = qos_round_fused._cache_size()
    for n in (1, 7, 33, 100, 255, 256):  # all ≤ default block_n=256
        round_n(n)
    assert qos_round_fused._cache_size() == before, \
        "steady-state backlog lengths must share one compiled executable"
    for n in (257, 300, 511, 513, 700, 1000):  # multi-block: pow2 buckets
        round_n(n)
    grown = qos_round_fused._cache_size() - before
    assert grown <= 2, f"expected ≤2 pow2 shapes (512, 1024), got {grown}"


# ------------------------------------------------------- telemetry ----------


def test_telemetry_queue_depth_qos():
    """Satellite regression: in QoS mode ``queue_depth`` must report the
    live per-tenant backlog, not the unused global semaphore (which reads
    0 while thousands queue)."""
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots=2, tenants={"a": 1.0, "b": 2.0})
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=1,
                    tenant_id=("a", "b")[i % 2]) for i in range(40)]
    eng.submit_batch(reqs)
    tel = eng.telemetry()
    assert tel["queue_depth"] == tel["backlog"] == 40
    while eng.stats.finished < 40:
        eng.step(lambda lg: np.zeros(len(lg), np.int64))
    assert eng.telemetry()["queue_depth"] == 0


# ------------------------------------------------- paged attention ----------


def _attn_run(n_slots, K, vocab=50, n_req=10):
    eng = ContinuousBatchingEngine(
        lambda a: None, lambda r: None, n_slots=n_slots,
        tenants={"a": 1.0}, clock=lambda: 0.0)
    eng.megastep_model = make_paged_attn_model(
        jax.random.PRNGKey(0), vocab=vocab, d=16, n_slots=n_slots,
        capacity=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(1, vocab, 5)),
                    max_new_tokens=6, tenant_id="a") for i in range(n_req)]
    eng.submit_batch(reqs)
    launches = 0
    while eng.stats.finished < n_req and launches < 100:
        eng.megastep(K, token_fn=paged_attn_token_fn,
                     admit_fn=paged_attn_admit_fn)
        launches += 1
    assert eng.stats.finished == n_req
    return [r.out_tokens for r in reqs]


def test_paged_attention_megastep():
    """Real paged decode attention + sampling runs inside the scanned
    round (in-graph prompt prefill at admission, ring-cursor KV writes),
    and per-request streams are invariant to slot count and K — the
    decode depends only on the request's own tokens, never on which slot
    or scan round served it."""
    a = _attn_run(n_slots=4, K=8)
    assert all(len(t) == 6 and all(0 <= x < 50 for x in t) for t in a)
    b = _attn_run(n_slots=2, K=4)
    assert a == b
