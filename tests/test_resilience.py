"""Self-healing serving engine (PR 7) — sentinels, faults, recovery ladder.

  * sentinel units: health-bit layout (mirrored vs deep), decode_health,
    clean runs report an all-zero health stream on both serving paths;
  * `faults.FaultPlan` is seed-deterministic (same seed → byte-identical
    schedule) and each injector actually trips its sentinel bit:
    KV_COUNTER → ``H_KV_CONSERVE``, STUCK_SLOT (+ watchdog) →
    ``H_STUCK``, NAN_LOGIT → ``H_NAN``, DOUBLE_RELEASE → the deep
    device-side partition/conservation bits;
  * `scheduler.quarantine` releases the slot's blocks (host mirror AND
    persistent device pool), returns the slot unit to admission, resets
    the request, and the engine still audits clean and drains;
  * `scheduler.audit_kv` rebuilds the free queue / block semaphore from
    block-table ground truth after counter corruption and aliasing;
  * tentpole chaos property: random seeded FaultPlans (capacity kinds)
    against a chunked block-paged engine on BOTH drives — every request
    reaches a terminal state, the exit audit is clean, and the ladder's
    recovery counters surface in ``telemetry()["recovery"]``;
  * tentpole equivalence property: a host-loop ResilientEngine and a
    megastep ResilientEngine fed the SAME plan stay bit-identical —
    token streams, stats, recovery actions, and the telemetry stream
    (deep device-only health bits masked) — incl. 2³² QoS ticket wrap;
  * rung 4: a mid-run CRASH restores the snapshot (through
    `checkpoint.manager.CheckpointManager`) and the deterministic replay
    converges to the exact final state of the uncrashed run; NAN poison
    escalates straight to rung 4 and the restore clears it.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

import test_chunked_prefill as tcp
import test_megastep as tms

from repro.checkpoint.manager import CheckpointManager
from repro.resilience import (
    CAPACITY_KINDS,
    CRASH,
    DOUBLE_RELEASE,
    FaultEvent,
    FaultPlan,
    KV_COUNTER,
    NAN_LOGIT,
    STUCK_SLOT,
    ResilientEngine,
    apply_fault,
    exit_audit,
)
from repro.serving import sentinels as sn
from repro.serving.engine_state import rid_token_fn
from repro.serving.scheduler import ContinuousBatchingEngine

DT = tms.DT
_IDENT = tms._IDENT
_rid_step_fn = tms._rid_step_fn


def _mk_eng(clk, *, watchdog=0, n_slots=4, kv_pool=(16, 4),
            chunked=(5, 9, 16), use_kernel=True, wrap=False):
    """The chunked block-paged engine of tests/test_chunked_prefill.py,
    plus the PR-7 watchdog — the richest state for faults to corrupt."""
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots,
        tenants={"gold": 2.0, "bronze": 1.0}, use_kernel=use_kernel,
        clock=lambda: clk[0], kv_pool=kv_pool, chunked_prefill=chunked,
        prompt_cap=32, watchdog=watchdog)
    eng._clock_box = clk
    if wrap:
        base = jnp.uint32((1 << 32) - 7)
        eng.qos = eng.qos._replace(
            ticket=jnp.full((2,), base), grant=jnp.full((2,), base),
            consumed=jnp.full((2,), base))
    return eng


def _drain(rz, reqs, *, mega, max_rounds=240, K=8):
    """Drive a ResilientEngine until every request reaches a terminal
    state and no requeue is pending (round-indexed virtual clock, so
    rung-4 rewinds stay time-consistent)."""
    eng = rz.engine
    spent = 0
    while spent < max_rounds:
        if (all(q.done_event.is_set() for q in reqs)
                and not rz._retryq and not eng.active):
            break
        if mega:
            base = eng._round_no
            nows = np.asarray([(base + k) * DT for k in range(K)],
                              np.float32)
            rz.megastep(K, token_fn=rid_token_fn, nows=nows)
            spent += K
        else:
            eng._clock_box[0] = eng._round_no * DT
            rz.step(_IDENT)
            spent += 1
    return spent


# ------------------------------------------------------- sentinel units ----


def test_health_bit_layout():
    """Every bit is a distinct power of two; the mirrored mask separates
    the host-computable bits from the deep device-only ones."""
    bits = list(sn.HEALTH_BITS.values())
    assert len(set(bits)) == len(bits)
    for b in bits:
        assert b > 0 and b & (b - 1) == 0
    for b in (sn.H_SLOT_CONSERVE, sn.H_CREDIT_NEG, sn.H_KV_CONSERVE,
              sn.H_BANKER, sn.H_STUCK):
        assert b & sn.HEALTH_MIRRORED_MASK
    for b in (sn.H_KV_PARTITION, sn.H_NAN):
        assert not b & sn.HEALTH_MIRRORED_MASK


def test_decode_health():
    assert sn.decode_health(0) == []
    got = set(sn.decode_health(sn.H_STUCK | sn.H_KV_CONSERVE | sn.H_NAN))
    assert got == {"stuck", "kv_conserve", "nan"}


def test_clean_run_health_all_zero_both_paths():
    """A fault-free run reports health == 0 every round on the host loop
    AND through the in-scan ring (sentinels add no false positives)."""
    for mega in (False, True):
        eng = _mk_eng([0.0], watchdog=6)
        reqs = tcp._workload(3, 10, 0.0)
        rz = ResilientEngine(eng)
        eng.submit_batch(reqs)
        _drain(rz, reqs, mega=mega)
        assert all(q.done_event.is_set() for q in reqs), mega
        assert rz.samples and all(s["health"] == 0 for s in rz.samples)
        assert rz.audit()["ok"]
        assert not rz.events


# ----------------------------------------------------- fault-plan units ----


def test_fault_plan_seed_deterministic():
    a = FaultPlan.random(123, rounds=40, n_faults=6)
    assert a == FaultPlan.random(123, rounds=40, n_faults=6)
    assert a != FaultPlan.random(124, rounds=40, n_faults=6)
    assert len(a.events) == 6
    assert all(1 <= e.round < 40 for e in a.events)
    assert all(e.kind in CAPACITY_KINDS for e in a.events)
    assert all(e.delta < 0 for e in a.events if e.kind == KV_COUNTER)
    wc = a.with_crash(7)
    assert len(wc.events) == 7
    assert [e for e in wc.events if e.kind == "crash"][0].round == 7
    assert a == FaultPlan.random(123, rounds=40, n_faults=6)  # no state


def test_kv_counter_leak_trips_conserve_bit_and_audit_repairs():
    """KV_COUNTER (delta<0) leaks free blocks → H_KV_CONSERVE fires the
    very next round; audit_kv reconciles the counter and the stream goes
    healthy again."""
    eng = _mk_eng([0.0])
    eng.submit_batch(tcp._workload(9, 6, 0.0))
    for k in range(3):
        eng._clock_box[0] = k * DT
        eng.step(_IDENT)
    assert apply_fault(eng, FaultEvent(round=3, kind=KV_COUNTER, delta=-2))
    eng._clock_box[0] = 3 * DT
    eng.step(_IDENT)
    assert eng.telemetry()["last_samples"][-1]["health"] & sn.H_KV_CONSERVE
    rep = eng.audit_kv()
    assert rep["counter_drift"] == 2 and not rep["victims"]
    eng._clock_box[0] = 4 * DT
    eng.step(_IDENT)
    assert eng.telemetry()["last_samples"][-1]["health"] == 0
    assert exit_audit(eng)["ok"]


def test_stuck_slot_watchdog_fires():
    """A force-parked slot that nothing pokes stops advancing; after W
    rounds the watchdog raises H_STUCK (host mirror of the in-scan
    last_adv check)."""
    eng = _mk_eng([0.0], watchdog=3)
    eng.submit_batch(tcp._workload(11, 3, 0.0))
    for k in range(2):
        eng._clock_box[0] = k * DT
        eng.step(_IDENT)
    assert apply_fault(eng, FaultEvent(round=2, kind=STUCK_SLOT, arg=5))
    hit = False
    for k in range(2, 14):
        eng._clock_box[0] = k * DT
        eng.step(_IDENT)
        if eng.telemetry()["last_samples"][-1]["health"] & sn.H_STUCK:
            hit = True
            break
    assert hit


def test_nan_logit_sticky_until_cleared():
    """NAN_LOGIT poisons persistently: H_NAN stays set round after round
    (the sticky host mirror of a poisoned device model)."""
    eng = _mk_eng([0.0])
    eng.submit_batch(tcp._workload(13, 4, 0.0))
    eng._clock_box[0] = 0.0
    eng.step(_IDENT)
    assert apply_fault(eng, FaultEvent(round=1, kind=NAN_LOGIT))
    for k in range(1, 4):
        eng._clock_box[0] = k * DT
        eng.step(_IDENT)
        assert eng.telemetry()["last_samples"][-1]["health"] & sn.H_NAN


# ----------------------------------------------- quarantine / audit_kv ----


def test_quarantine_releases_blocks_and_request_refinishes():
    eng = _mk_eng([0.0])
    reqs = tcp._workload(5, 6, 0.0)
    eng.submit_batch(reqs)
    for k in range(4):
        eng._clock_box[0] = k * DT
        eng.step(_IDENT)
    assert eng.active
    slot = sorted(eng.active)[0]
    victim = eng.active[slot]
    free_before = eng._kv_free_blocks
    held = victim.kv_blocks
    req = eng.quarantine(slot)
    assert req is victim
    assert slot in eng.free_slots and slot not in eng.active
    assert eng._kv_free_blocks == free_before + held
    assert req.slot is None and req.out_tokens == [] and req.kv_blocks == 0
    assert not req.done_event.is_set()  # still in flight
    assert eng.stats.quarantined == 1
    assert exit_audit(eng)["ok"]
    eng.submit(req)  # a quarantined request can go around again
    for k in range(4, 160):
        eng._clock_box[0] = k * DT
        eng.step(_IDENT)
        if all(r.done_event.is_set() for r in reqs):
            break
    assert all(r.done_event.is_set() for r in reqs)
    assert exit_audit(eng)["ok"]


def test_quarantine_on_megastep_engine_releases_device_pool():
    """On the scanned path the device block table is ground truth: the
    quarantined slot's pool row must be released (counter + free queue +
    pokes), its table row cleared, and the host mirrors resynced."""
    eng = _mk_eng([0.0])
    reqs = tcp._workload(5, 6, 0.0)
    eng.submit_batch(reqs)
    eng.megastep(4, token_fn=rid_token_fn,
                 nows=np.asarray([k * DT for k in range(4)], np.float32))
    assert eng.active
    slot = sorted(eng.active)[0]
    eng.quarantine(slot)
    tbl = np.asarray(eng._kv_state.tbl)
    assert (tbl[slot] == -1).all()
    assert exit_audit(eng)["ok"]  # free ∪ tables is a permutation again


def test_double_release_detected_in_scan_and_audit_rebuilds():
    """DOUBLE_RELEASE aliases a live block into the free queue — only the
    device physically holds block identities, so the DEEP sentinel bits
    catch it in-scan; audit_kv rebuilds the partition from the tables and
    quarantining the aliasing victims makes the exit audit clean."""
    eng = _mk_eng([0.0])
    eng.submit_batch(tcp._workload(7, 8, 0.0))
    eng.megastep(6, token_fn=rid_token_fn,
                 nows=np.asarray([k * DT for k in range(6)], np.float32))
    assert any(r.kv_blocks for r in eng.active.values())
    assert apply_fault(eng, FaultEvent(round=6, kind=DOUBLE_RELEASE))
    eng.megastep(2, token_fn=rid_token_fn,
                 nows=np.asarray([(6 + k) * DT for k in range(2)],
                                 np.float32))
    h = 0
    for s in eng.telemetry()["last_samples"]:
        h |= s["health"]
    assert h & (sn.H_KV_PARTITION | sn.H_KV_CONSERVE)
    rep = eng.audit_kv()
    for s in rep["victims"]:
        if s in eng.active:
            eng.quarantine(s)
    assert exit_audit(eng)["ok"]
    assert eng.stats.kv_audits == 1


# --------------------------------------------------- tentpole: chaos ----


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_chaos_property_drains_and_audits_clean(seed, mega):
    """ISSUE acceptance: under a random seeded FaultPlan of capacity
    faults the self-healing engine still drains EVERY request to a
    terminal state and exits with zero invariant violations; recovery
    actions surface in telemetry()["recovery"]."""
    eng = _mk_eng([0.0], watchdog=4)
    reqs = tcp._workload(seed, 10, 0.0)
    plan = FaultPlan.random(seed, rounds=24, n_faults=4,
                            kinds=CAPACITY_KINDS)
    rz = ResilientEngine(eng, plan=plan, react_every=2, retry_budget=2,
                         seed=seed)
    eng.submit_batch(reqs)
    _drain(rz, reqs, mega=mega)
    assert all(q.done_event.is_set() for q in reqs), \
        (seed, mega, [q.rid for q in reqs if not q.done_event.is_set()])
    audit = rz.audit()
    assert audit["ok"], (seed, mega, audit)
    rec = rz.telemetry()["recovery"]
    assert set(rec) == {"quarantined", "requeued", "kv_audits",
                        "kernel_fallbacks", "snapshots", "restores"}
    injected = [e for e in rz.events
                if e["action"] == "inject" and e["applied"]]
    if any(e["kind"] == KV_COUNTER for e in injected):
        assert rec["kv_audits"] >= 1  # the leak forced a rung-2 audit
    assert rec["requeued"] + rec["quarantined"] >= rec["requeued"]


# --------------------------------------------- tentpole: equivalence ----


def _compare_resilient(seed, deadline_frac, wrap, K=16, n_req=12):
    """Host-loop ResilientEngine vs megastep ResilientEngine, one shared
    FaultPlan: every observable matches round-for-round (deep
    device-only health bits masked)."""
    eh = _mk_eng([0.0], watchdog=3, wrap=wrap)
    em = _mk_eng([0.0], watchdog=3, wrap=wrap)
    rh = tcp._workload(seed, n_req, deadline_frac)
    rm = tcp._workload(seed, n_req, deadline_frac)
    plan = FaultPlan.random(seed, rounds=K, n_faults=3,
                            kinds=CAPACITY_KINDS)
    rzh = ResilientEngine(eh, plan=plan, react_every=4, seed=seed)
    rzm = ResilientEngine(em, plan=plan, react_every=4, seed=seed)
    eh.submit_batch(rh)
    em.submit_batch(rm)
    times = [k * DT for k in range(K)]
    for t in times:
        eh._clock_box[0] = t
        rzh.step(_IDENT)
    em._clock_box[0] = 0.0
    rzm.megastep(K, token_fn=rid_token_fn,
                 nows=np.asarray(times, np.float32))

    tag = f"seed={seed} wrap={wrap}"
    for a, b in zip(rh, rm):
        assert a.out_tokens == b.out_tokens, (tag, a.rid)
        assert a.admit_round == b.admit_round, (tag, a.rid)
        assert a.expired == b.expired and a.preempted == b.preempted, \
            (tag, a.rid)
        assert a.retries == b.retries, (tag, a.rid)
    assert len(rzh.samples) == len(rzm.samples) == K, tag
    for k, (a, b) in enumerate(zip(rzh.samples, rzm.samples)):
        assert set(a) == set(b), (tag, k)
        for key in a:
            va, vb = a[key], b[key]
            if key == "health":  # deep bits are device-only by design
                va &= sn.HEALTH_MIRRORED_MASK
                vb &= sn.HEALTH_MIRRORED_MASK
            assert va == vb, (tag, k, key, a[key], b[key])
    for f in ("finished", "expired", "preempted", "admitted", "quarantined",
              "requeued", "kv_audits", "kernel_fallbacks"):
        assert getattr(eh.stats, f) == getattr(em.stats, f), (tag, f)
    for f in eh.qos._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(eh.qos, f)), np.asarray(getattr(em.qos, f)),
            err_msg=f"{tag}:{f}")
    assert eh._qos_free == em._qos_free, tag
    assert eh._kv_free_blocks == em._kv_free_blocks, tag
    acts_h = [(e["round"], e["action"]) for e in rzh.events]
    acts_m = [(e["round"], e["action"]) for e in rzm.events]
    assert acts_h == acts_m, tag  # the ladder took identical actions


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([0.0, 0.4]),
       st.booleans())
def test_resilient_megastep_equals_host_loop_property(seed, deadline_frac,
                                                      wrap):
    """ISSUE acceptance: megastep(K) ≡ K·step() SURVIVES fault injection
    and recovery — both drives inject, detect, and heal at identical
    round boundaries, incl. 2³² QoS ticket wrap."""
    _compare_resilient(seed, deadline_frac, wrap)


# ------------------------------------------------- rung 4: crash/NaN ----


def test_crash_restore_replays_to_identical_state(tmp_path):
    """ISSUE acceptance: a mid-run CRASH (snapshot → restore →
    deterministic replay) converges to the exact final state of the
    uncrashed run."""
    K = 20
    base_plan = FaultPlan.random(5, rounds=K, n_faults=2,
                                 kinds=CAPACITY_KINDS)
    outs = []
    for crash_round in (None, 9):
        eng = _mk_eng([0.0], watchdog=4)
        reqs = tcp._workload(5, 10, 0.0)
        plan = (base_plan if crash_round is None
                else base_plan.with_crash(crash_round))
        ck = CheckpointManager(str(tmp_path / f"ck_{crash_round}"), keep=8)
        rz = ResilientEngine(eng, plan=plan, react_every=2, seed=5,
                             ckpt=ck, snapshot_every=4)
        eng.submit_batch(reqs)
        rz.megastep(K, token_fn=rid_token_fn,
                    nows=np.asarray([k * DT for k in range(K)],
                                    np.float32))
        assert rz.audit()["ok"]
        outs.append((rz, eng, [list(r.out_tokens) for r in reqs],
                     [(r.expired, r.admit_round) for r in reqs]))
    (rz0, e0, tok0, meta0), (rz1, e1, tok1, meta1) = outs
    assert tok1 == tok0
    assert meta1 == meta0
    assert e1.stats.restores >= 1 and e1.stats.snapshots >= 1
    assert e0.stats.restores == 0
    assert any(e["action"] == "crash" for e in rz1.events)
    assert e1.stats.finished == e0.stats.finished
    np.testing.assert_array_equal(np.asarray(e0.qos.grant),
                                  np.asarray(e1.qos.grant))


def test_crash_on_host_loop_restores_and_drains(tmp_path):
    """The host drive's crash path: restore + in-place replay inside
    step(), then the run drains clean."""
    eng = _mk_eng([0.0], watchdog=4)
    reqs = tcp._workload(17, 8, 0.0)
    plan = FaultPlan(seed=0, events=(FaultEvent(round=6, kind=CRASH),))
    ck = CheckpointManager(str(tmp_path), keep=8)
    rz = ResilientEngine(eng, plan=plan, react_every=2, seed=0, ckpt=ck,
                         snapshot_every=4)
    eng.submit_batch(reqs)
    _drain(rz, reqs, mega=False)
    assert all(r.done_event.is_set() for r in reqs)
    assert eng.stats.restores == 1
    assert rz.audit()["ok"]


def test_nan_escalates_to_rung4_restore(tmp_path):
    """NAN health skips the lower rungs (nothing below a restore can
    un-poison a model): the sticky flag is cleared by the snapshot
    restore and the run finishes clean."""
    eng = _mk_eng([0.0], watchdog=0)
    reqs = tcp._workload(13, 8, 0.0)
    plan = FaultPlan(seed=0, events=(FaultEvent(round=5, kind=NAN_LOGIT),))
    ck = CheckpointManager(str(tmp_path), keep=8)
    rz = ResilientEngine(eng, plan=plan, react_every=2, seed=0, ckpt=ck,
                         snapshot_every=4)
    eng.submit_batch(reqs)
    _drain(rz, reqs, mega=False)
    assert all(r.done_event.is_set() for r in reqs)
    assert eng.stats.restores >= 1
    assert not eng._nonfinite_sticky
    assert rz.audit()["ok"]
    assert rz.samples[-1]["health"] == 0


# --------------------------------------------- PR 8: corruption kinds ----


def test_bit_flip_trips_audit_and_drains():
    """BIT_FLIP corrupts one live block-table entry on the device pool;
    the deep sentinels see the aliasing/conservation break and the
    ladder's rung-2 audit rebuilds block truth — the run still drains
    and the exit audit is clean."""
    from repro.resilience import BIT_FLIP

    clk = [0.0]
    eng = _mk_eng(clk, watchdog=4)
    reqs = tcp._workload(5, 8, 0.0)
    plan = FaultPlan(seed=0, events=(
        FaultEvent(round=6, kind=BIT_FLIP, arg=2, delta=1),))
    rz = ResilientEngine(eng, plan=plan, react_every=2, seed=0)
    eng.submit_batch(reqs)
    _drain(rz, reqs, mega=True)
    assert all(r.done_event.is_set() for r in reqs)
    rec = rz.telemetry()["recovery"]
    assert rec["kv_audits"] >= 1, rec
    assert any(e["action"] == "audit_kv" for e in rz.events)
    assert rz.audit()["ok"], rz.audit()["violations"]


def test_torn_shard_restore_falls_back_to_older_snapshot(tmp_path):
    """TORN_SHARD truncates the newest checkpoint's shard files on disk
    (a half-written write at crash time).  The next rung-4 restore finds
    the torn step unloadable, logs the fallback, walks to the previous
    snapshot in history, and replays forward — the run converges anyway."""
    from repro.resilience import TORN_SHARD

    clk = [0.0]
    eng = _mk_eng(clk, watchdog=4)
    reqs = tcp._workload(19, 8, 0.0)
    plan = FaultPlan(seed=0, events=(
        FaultEvent(round=9, kind=TORN_SHARD),
        FaultEvent(round=10, kind=CRASH),
    ))
    ck = CheckpointManager(str(tmp_path), keep=8)
    rz = ResilientEngine(eng, plan=plan, react_every=2, seed=0, ckpt=ck,
                         snapshot_every=4)
    eng.submit_batch(reqs)
    # single megasteps from round 0 so the in-scan restore never rewinds
    # past the launch base (the torn fallback lands on an OLDER snapshot)
    _drain(rz, reqs, mega=True, K=24)
    assert all(r.done_event.is_set() for r in reqs)
    falls = [e for e in rz.events if e["action"] == "torn_shard_fallback"]
    assert falls and falls[0]["step"] == 8
    assert any(e["action"] == "restore" and e["at_round"] < 8
               for e in rz.events if "at_round" in e) or \
        eng.stats.restores >= 1
    assert rz.audit()["ok"], rz.audit()["violations"]
