"""Refcounted prefix sharing — the PR-9 tentpole tests:

  * unit: `serving.prefix` hash/lookup/register round trip — longest
    unbroken chain, tail hits, gen-stamp weak invalidation;
  * property: the refcounted conservation invariant
    ``{free_q[ticket..grant)} ∪ {blocks with refcnt > 0} = {0..NB−1}``
    with ``Σ table references = Σ refcnt`` holds at every round under
    admit / park / preempt / release churn with shared prefixes, incl.
    the block counters crossing 2³²;
  * property: with ``prefix_cache=`` enabled, ``megastep(K)`` stays
    round-for-round bit-identical to K ``step()`` calls — token streams,
    block IDENTITIES (tables, free-queue order, refcounts), the weak
    cache, telemetry samples (prefix_hits / blocks_shared / cow_copies),
    incl. 2³² pool-counter wrap;
  * zero-flop cached prefill: a fully-covered admit attaches by incref
    only — prefill_pos lands AT plen, no prefill chunk is ever
    scheduled for it, and ``prefix_hits`` counts it on both paths;
  * copy-on-write correctness: token streams through the REAL paged
    pool-attention model are bit-identical with sharing on vs off (a
    broken COW would corrupt the shared tail for every sharer);
  * satellite: `submit()` validates lifetime demand against the
    POST-divergence demand when a cached prefix covers part of the
    prompt (admits what the cache makes feasible, still rejects the
    truly infeasible).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.functional import (
    make_block_pool,
    pool_free_count,
    pool_incref,
    pool_release,
    pool_try_alloc,
)
from repro.resilience.recovery import exit_audit
from repro.serving.engine_state import (
    chunked_prefill_token_fn,
    make_paged_pool_model,
    rid_token_fn,
)
from repro.serving.prefix import (
    cache_lookup,
    cache_register,
    make_prefix_cache,
    prompt_hashes,
)
from repro.serving.scheduler import ContinuousBatchingEngine, Request

DT = 0.25  # f32-exact virtual-time grid (see tests/test_megastep.py)

_IDENT = lambda lg: lg.astype(np.int64)  # noqa: E731


def _rid_step_fn(active):
    return np.array([r.rid * 1000 + len(r.out_tokens) for r in active],
                    np.int64)


# --------------------------------------------- prefix cache unit ------------


def test_prompt_hash_lookup_register_roundtrip():
    """Register a completed prefill, look the prefix back up: full blocks
    chain from block 0, the tail entry needs an exact tail length, and a
    release (gen bump) weakly kills every entry for the freed block."""
    BS, W = 4, 4
    pool = make_block_pool(8)
    pool, ids, _, _ = pool_try_alloc(
        pool, jnp.asarray([3], jnp.int32), 3,
        park=jnp.asarray([False]), deficit=jnp.asarray([0]))
    tbl = jnp.asarray([[int(ids[0, 0]), int(ids[0, 1]), int(ids[0, 2]),
                        -1]], jnp.int32)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]       # 2 full blocks + tail 2
    ph = jnp.asarray([prompt_hashes(prompt, BS, W)], jnp.uint32)
    cache = cache_register(make_prefix_cache(16), pool, ph,
                           jnp.asarray([len(prompt)], jnp.int32), tbl,
                           jnp.asarray([True]), BS)
    # identical prompt: full chain + tail hit → covered to plen
    c, bids, tail, cov = cache_lookup(cache, pool, ph,
                                      jnp.asarray([len(prompt)]), BS)
    assert int(c[0]) == 2 and int(cov[0]) == len(prompt)
    assert int(tail[0]) == int(tbl[0, 2])
    assert bids[0, :2].tolist() == [int(tbl[0, 0]), int(tbl[0, 1])]
    # same 2-block prefix, different tail: chain only, no tail hit
    other = prompt[:8] + [7, 7, 7]
    ph2 = jnp.asarray([prompt_hashes(other, BS, W)], jnp.uint32)
    c2, _, tail2, cov2 = cache_lookup(cache, pool, ph2,
                                      jnp.asarray([len(other)]), BS)
    assert int(c2[0]) == 2 and int(tail2[0]) == -1 and int(cov2[0]) == 8
    # free block 1 of the chain → its gen bumps → chain cut at block 1
    pool = pool_release(pool, ids[:, 1:2], jnp.asarray([True]))
    c3, _, tail3, _ = cache_lookup(cache, pool, ph,
                                   jnp.asarray([len(prompt)]), BS)
    assert int(c3[0]) == 1 and int(tail3[0]) == -1


def test_pool_incref_is_semaphore_silent():
    """Attaching a sharer moves NO counter and pokes NO bucket — sharing
    a live block is free at the semaphore level; the release then frees
    only at refcnt 0 (the conditional `post`)."""
    pool = make_block_pool(8)
    pool, ids, _, _ = pool_try_alloc(
        pool, jnp.asarray([2], jnp.int32), 2,
        park=jnp.asarray([False]), deficit=jnp.asarray([0]))
    before = (int(pool.sema.ticket), int(pool.sema.grant),
              np.asarray(pool.sema.bucket_seq).copy())
    pool = pool_incref(pool, ids[0], jnp.ones(2, bool))
    assert int(pool.sema.ticket) == before[0]
    assert int(pool.sema.grant) == before[1]
    np.testing.assert_array_equal(np.asarray(pool.sema.bucket_seq),
                                  before[2])
    assert np.asarray(pool.refcnt)[np.asarray(ids[0])].tolist() == [2, 2]
    # first release: decref only — free count must NOT move
    pool = pool_release(pool, ids, jnp.asarray([True]))
    assert int(pool_free_count(pool)) == 6
    # second release: last sharer leaves → both blocks free
    pool = pool_release(pool, ids, jnp.asarray([True]))
    assert int(pool_free_count(pool)) == 8


# ------------------------------------- refcounted conservation property -----


def _check_refcounted_conservation(pool, tbl, NB, tag=""):
    """The PR-9 generalization of the PR-4 partition check:
    free ∪ {refcnt > 0} tiles {0..NB−1} and table refs == refcnt."""
    t = int(np.uint32(np.asarray(pool.sema.ticket)))
    g = int(np.uint32(np.asarray(pool.sema.grant)))
    free = ((g - t) + (1 << 32)) % (1 << 32)
    assert free <= NB, (tag, free)
    refcnt = np.asarray(pool.refcnt)
    assert (refcnt >= 0).all(), (tag, "negative refcount")
    live = np.flatnonzero(refcnt > 0).tolist()
    assert len(live) == NB - free, (tag, len(live), NB - free)
    fq = np.asarray(pool.free_q)
    free_ids = [int(fq[(t + j) % NB]) for j in range(free)]
    assert sorted(live + free_ids) == list(range(NB)), (tag, "ids lost")
    tb = np.asarray(tbl)
    refs = np.bincount(tb[tb >= 0], minlength=NB)
    np.testing.assert_array_equal(refs, refcnt,
                                  err_msg=f"{tag}: table refs != refcnt")


def _mk_share(clk, *, n_slots=4, kv_pool=(16, 4, 8), chunked=(5, 9, 16),
              prefix=8, use_kernel=True, wrap=False):
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots,
        tenants={"gold": 2.0, "bronze": 1.0}, use_kernel=use_kernel,
        clock=lambda: clk[0], kv_pool=kv_pool, chunked_prefill=chunked,
        prompt_cap=32, prefix_cache=prefix)
    if wrap:
        # park the replica pool's block-semaphore counters just below 2³²
        # (megastep adopts the replica, so the device wraps identically)
        eng._kv_hpool = make_block_pool(kv_pool[0], table_size=64,
                                        start=(1 << 32) - 5)
        eng._kv_sema = eng._kv_hpool.sema
    return eng


def _share_workload(seed, n_req, deadline_frac):
    """Shared 8-token prefix (2 full blocks) + a random tail: later
    admissions chain onto live blocks; identical-tail collisions produce
    full-prompt hits whose decodes then copy-on-write."""
    rng = np.random.default_rng(seed)
    names = ["gold", "bronze"]
    reqs = []
    for i in range(n_req):
        dl = DT * int(rng.integers(0, 20)) if rng.random() < deadline_frac \
            else None
        tail = [1 + int(x)
                for x in rng.integers(1, 4, int(rng.integers(0, 5)))]
        reqs.append(Request(
            rid=i, prompt=[7] * 8 + tail,
            max_new_tokens=1 + int(rng.integers(0, 6)),
            tenant_id=names[int(rng.integers(0, 2))], deadline=dl))
    return reqs


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.4]),
       st.booleans())
def test_refcounted_conservation_property(seed, deadline_frac, wrap):
    """ISSUE acceptance: the generalized conservation invariant holds at
    EVERY round of a sharing engine under admission, incref attach,
    park/resume, deadline preemption, copy-on-write, and release churn —
    incl. the block counters crossing 2³² — and the drained engine
    passes the refcount-aware exit audit."""
    clk = [0.0]
    eng = _mk_share(clk, wrap=wrap)
    reqs = _share_workload(seed, 12, deadline_frac)
    eng.submit_batch(reqs)
    NB = 16
    for k in range(60):
        clk[0] = k * DT
        eng.step(_IDENT)
        _check_refcounted_conservation(eng._kv_hpool, eng._kv_htbl, NB,
                                       f"seed={seed} round {k}")
        if eng.stats.finished + eng.stats.expired == len(reqs):
            break
    assert eng.stats.finished + eng.stats.expired == len(reqs)
    assert int(pool_free_count(eng._kv_hpool)) == NB
    audit = exit_audit(eng)
    assert audit["ok"], audit["violations"]


# ------------------------------------- sharing megastep ≡ host loop ---------


def _compare_sharing_engines(seed, deadline_frac, wrap, *, K=20, n_req=12):
    clk = [0.0]
    eh = _mk_share(clk, wrap=wrap)
    em = _mk_share(clk, wrap=wrap)
    rh = _share_workload(seed, n_req, deadline_frac)
    rm = _share_workload(seed, n_req, deadline_frac)
    eh.submit_batch(rh)
    em.submit_batch(rm)
    times = [k * DT for k in range(K)]
    for t in times:
        clk[0] = t
        eh.step(_IDENT)
    clk[0] = 0.0
    em.megastep(K, token_fn=rid_token_fn,
                nows=np.asarray(times, np.float32))
    for a, b in zip(rh, rm):
        tag = f"seed={seed} rid={a.rid}"
        assert a.out_tokens == b.out_tokens, (tag, a.out_tokens,
                                              b.out_tokens)
        assert a.admit_round == b.admit_round, tag
        assert a.expired == b.expired and a.preempted == b.preempted, tag
    # block IDENTITIES, not just counters: tables, refcounts, free-queue
    # ORDER, generation stamps, and the weak cache must all agree — any
    # divergence in release batching or slot assignment shows up here
    dev = em._kv_state
    np.testing.assert_array_equal(eh._kv_htbl, np.asarray(dev.tbl),
                                  err_msg=str(seed))
    for f in ("refcnt", "gen", "free_q"):
        np.testing.assert_array_equal(
            np.asarray(getattr(eh._kv_hpool, f)),
            np.asarray(getattr(dev.pool, f)), err_msg=f"seed={seed}:{f}")
    for f in eh._kv_cache._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(eh._kv_cache, f)),
            np.asarray(getattr(dev.cache, f)), err_msg=f"seed={seed}:{f}")
    assert int(eh._kv_sema.ticket) == int(dev.pool.sema.ticket), seed
    assert int(eh._kv_sema.grant) == int(dev.pool.sema.grant), seed
    np.testing.assert_array_equal(np.asarray(eh._kv_sema.bucket_seq),
                                  np.asarray(dev.pool.sema.bucket_seq),
                                  err_msg=str(seed))
    assert eh._kv_free_blocks == em._kv_free_blocks, seed
    assert eh.stats.prefix_hits == em.stats.prefix_hits, seed
    assert eh.stats.cow_copies == em.stats.cow_copies, seed
    assert eh.stats.admitted == em.stats.admitted
    return eh, em


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.4]),
       st.booleans())
def test_sharing_megastep_equals_host_loop_property(seed, deadline_frac,
                                                    wrap):
    """ISSUE acceptance: with the prefix cache enabled, megastep(K) ≡ K
    step() calls bit-identically — including the refcounted pool's full
    identity state and the new telemetry probes — under shared-prefix
    traffic with preemption and 2³² counter wrap."""
    _compare_sharing_engines(seed, deadline_frac, wrap)


def test_sharing_round_samples_bit_identical():
    """The per-round telemetry samples (incl. prefix_hits /
    blocks_shared / cow_copies and the health bitmask) are equal as
    DICTS between a host step and a 1-round megastep, every round."""
    clk = [0.0]
    eh = _mk_share(clk)
    em = _mk_share(clk)
    def wl():
        return [Request(rid=i, prompt=[7] * 10, max_new_tokens=4,
                        tenant_id="gold" if i % 2 else "bronze")
                for i in range(10)]

    eh.submit_batch(wl())
    em.submit_batch(wl())
    shared_seen = 0
    for k in range(24):
        clk[0] = k * DT
        eh.step(_IDENT)
        em.megastep(1, token_fn=rid_token_fn,
                    nows=np.asarray([0.0], np.float32))
        hs, ms = eh._last_samples[-1], em._last_samples[-1]
        assert hs == ms, (k, {key: (hs[key], ms.get(key)) for key in hs
                              if hs[key] != ms.get(key)})
        assert hs["health"] == 0, k
        shared_seen = max(shared_seen, hs["blocks_shared"])
    assert shared_seen > 0, "sharing never engaged"
    assert eh.stats.prefix_hits > 0 and eh.stats.cow_copies > 0


# ------------------------------------- zero-flop cached prefill -------------


def test_fully_covered_admit_skips_prefill_entirely():
    """A request whose WHOLE prompt is cache-resident admits by incref
    only: its KV cursor starts at plen (zero prefill flops — no chunk is
    ever scheduled for it), no new blocks are taken for the covered
    tokens, and prefix_hits counts it."""
    clk = [0.0]
    eng = _mk_share(clk, n_slots=2)
    # long-decoding holder: its blocks stay live (refcnt > 0) so the
    # weak cache entries registered at its prefill completion stay valid
    first = Request(rid=0, prompt=[5] * 10, max_new_tokens=12,
                    tenant_id="gold")
    eng.submit_batch([first])
    k = 0
    while first.prefill_pos < 10:       # registration at completion round
        clk[0] = k * DT
        eng.step(_IDENT)
        k += 1
    assert eng.stats.prefix_hits == 0
    chunks_before = eng.stats.prefill_chunks
    tokens_seen = []
    second = Request(rid=1, prompt=[5] * 10, max_new_tokens=2,
                     tenant_id="gold")
    eng.submit_batch([second])
    for k in range(k, k + 12):
        clk[0] = k * DT
        eng.step(_IDENT)
        tokens_seen.append(eng._last_samples[-1]["prefill_tokens"])
        if second.finish_t:
            break
    assert len(second.out_tokens) == 2
    assert second.prefill_pos >= 10
    assert eng.stats.prefix_hits == 1            # the zero-flop admit
    assert eng.stats.prefill_chunks == chunks_before  # no chunk scheduled
    assert sum(tokens_seen) == 0                 # zero prefill flops
    assert eng.stats.cow_copies >= 1             # tail diverged via COW


# ------------------------------------- COW correctness (real attention) -----


def _attn_share_run(prefix, *, K=8, n_slots=4, vocab=40):
    """Shared-prefix traffic through the REAL pool-attention model —
    identical 16-token system prompt, 7-token user tails.  Lifetimes are
    staggered so later admissions OVERLAP live holders (weak entries die
    with their blocks): rid0 (distinct tail) retires early, rid1 decodes
    long keeping its registered blocks live, and rid2–5 repeat rid1's
    prompt verbatim — full-prompt hits whose decodes then copy-on-write
    the shared tail block."""
    NB, BS = 32, 4
    eng = ContinuousBatchingEngine(
        lambda a: None, lambda r: None, n_slots, tenants={"a": 1.0},
        clock=lambda: 0.0, kv_pool=(NB, BS, 16), prompt_cap=64,
        chunked_prefill=(6, 12), prefix_cache=prefix)
    eng.megastep_model = make_paged_pool_model(
        jax.random.PRNGKey(0), vocab=vocab, d=16, num_blocks=NB,
        block_size=BS)
    rng = np.random.default_rng(9)
    sysp = list(rng.integers(1, vocab, 16))
    tails = [list(rng.integers(1, vocab, 7)) for _ in range(2)]
    mx = [2, 16, 12, 12, 4, 4]
    prompts = [sysp + tails[0]] + [sysp + tails[1]] * 5
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=mx[i],
                    tenant_id="a") for i, p in enumerate(prompts)]
    n_req = len(reqs)
    eng.submit_batch(reqs)
    launches = 0
    while eng.stats.finished < n_req and launches < 120:
        eng.megastep(K, token_fn=chunked_prefill_token_fn)
        launches += 1
    assert eng.stats.finished == n_req
    assert eng.telemetry()["kv_blocks_free"] == NB
    return eng, [r.out_tokens for r in reqs]


def test_cow_streams_match_no_sharing_through_real_attention():
    """ISSUE acceptance: copy-on-write is CORRECT — token streams through
    the real paged attention are bit-identical with sharing on vs off.
    A COW bug (decode writing into a still-shared block, or a copy
    missing the filled tail) corrupts every sharer's KV and shows here."""
    _, plain = _attn_share_run(0)
    es, shared = _attn_share_run(64)
    assert shared == plain
    # sharing actually happened (prefix attaches and/or COW takes)
    tel = es.telemetry()
    assert tel["prefix_hits"] + tel["cow_copies"] > 0 or \
        es.stats.prefix_hits + es.stats.cow_copies > 0


# ------------------------------------- submit-time post-divergence gate -----


def test_submit_validates_against_post_divergence_demand():
    """ISSUE satellite: lifetime demand beyond pool capacity is accepted
    when a cached prefix covers enough blocks (demand − cached ≤ NB) and
    still rejected when no usable prefix exists."""
    clk = [0.0]
    eng = _mk_share(clk, n_slots=2, kv_pool=(8, 4, 16), chunked=(5, 9, 8),
                    prefix=64)
    wp = [(3 + 7 * i) % 31 + 1 for i in range(24)]  # varied: disperses keys
    bp = wp + [9, 8, 7, 6]
    # no cache yet: 8-block pool, demand cdiv(28 + 8, 4) = 9 > 8 → reject
    big = Request(rid=0, prompt=list(bp), max_new_tokens=8,
                  tenant_id="gold")
    with pytest.raises(ValueError):
        eng.submit(big)
    # warm the cache with a feasible 24-token prompt (6 full blocks) and
    # keep it DECODING — live refcounts keep the weak entries valid
    warm = Request(rid=1, prompt=list(wp), max_new_tokens=6,
                   tenant_id="gold")
    eng.submit_batch([warm])
    k = 0
    while warm.prefill_pos < 24:        # registration at completion round
        clk[0] = k * DT
        eng.step(_IDENT)
        k += 1
        assert k < 30
    # the same over-capacity request now shares 6 cached blocks:
    # post-divergence demand 9 − 6 = 3 ≤ 8 → accepted
    big2 = Request(rid=2, prompt=list(bp), max_new_tokens=8,
                   tenant_id="gold")
    eng.submit(big2)
    # an over-capacity prompt with NO cached prefix still rejects
    alien = Request(rid=3, prompt=[6] * 28, max_new_tokens=8,
                    tenant_id="gold")
    with pytest.raises(ValueError):
        eng.submit(alien)
