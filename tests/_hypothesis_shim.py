"""Minimal fallback for `hypothesis` so the suite collects and runs
everywhere (hypothesis is an *optional* test dependency — see
pyproject.toml `[project.optional-dependencies] test`).

When hypothesis is installed, the real library is used (tests import it
first and only fall back here on ImportError).  The shim draws a fixed
number of seeded pseudo-random examples per property — no shrinking, no
coverage guidance, far weaker than hypothesis — but it keeps the property
assertions executing instead of crashing collection.
"""

from __future__ import annotations

import random

_SHIM_MAX_EXAMPLES = 15  # cap: shim examples run inside ONE test call
_SEED = 0x7AA0B5E5


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 32):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def tuples(*ss):
        return _Strategy(lambda r: tuple(s.example(r) for s in ss))

    @staticmethod
    def lists(elements, min_size=0, max_size=10, **_kw):
        return _Strategy(
            lambda r: [elements.example(r)
                       for _ in range(r.randint(min_size, max_size))])

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))


strategies = _Strategies()


def settings(max_examples: int = 20, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*ss):
    def deco(fn):
        def wrapper():
            n = min(getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 20)),
                    _SHIM_MAX_EXAMPLES)
            rnd = random.Random(_SEED)
            for _ in range(n):
                vals = [s.example(rnd) for s in ss]
                fn(*vals)
        # NOT functools.wraps: pytest must see a zero-arg signature (the
        # drawn values are not fixtures), so don't expose __wrapped__.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
